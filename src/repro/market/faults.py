"""Deterministic market fault injection (chaos layer for the fleet PR).

The resilience claims of the fleet manager ("the workload stays up") are
only testable if the market can be made to misbehave *on demand*.  This
module injects faults as scripted or stochastic (seeded, pre-drawn) event
sources that **compose with the existing PRICE_TICK machinery** instead of
bypassing it:

* ``capacity-crunch`` — a per-pool utilization bias added to the live
  demand signal *before* the price processes clear: prices rise through the
  normal clearing curve, waves fire through the normal registry comparison.
* ``price-spike``    — a per-pool additive bias on the tick's standard-
  normal shock vector (both the fused family step and the scalar oracle
  consume the biased shocks, so the two engine paths stay bit-identical).
* ``pool-outage``    — a transient whole-pool outage: every active host of
  the pool is deactivated at the window start (residents evicted through
  the ordinary interruption lifecycle, cause ``"fault-outage"``) and
  reactivated at the window end.
* ``storm``          — a correlated interruption storm: at the fault time a
  fraction of each affected pool's *resident running spot VMs* is reclaimed
  immediately (cause ``"fault-storm"``), lowest bids first — the provider
  reclaiming capacity across pools at once, ignoring price admission.

Every fault is a :class:`FaultEvent` with an absolute start time; stochastic
scenarios (``random-storms``) pre-draw their whole schedule from the seed at
construction, so two runs at the same seed are bit-identical (the chaos-
determinism contract, regression-tested in ``tests/market/test_faults``).

Scenario generators register in :data:`FAULT_REGISTRY`
(``@register_fault_scenario("name")``) and are resolved by ``FaultSpec`` /
the builder, PR 4 registry style.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.causes import InterruptionCause
from ..obs.eventlog import NULL_RECORDER
from ..core.registry import Registry

_EPS = 1e-9

#: fault kinds an event may carry (validated at injector construction)
FAULT_KINDS = ("capacity-crunch", "price-spike", "pool-outage", "storm")

#: string-keyed registry of fault *scenarios* — factories
#: ``(n_pools, horizon, tick_interval, seed, **params) -> Sequence[FaultEvent]``;
#: ``FaultSpec`` and the builder resolve against it
FAULT_REGISTRY = Registry("fault scenario")
register_fault_scenario = FAULT_REGISTRY.register


@dataclass(frozen=True)
class FaultEvent:
    """One injected fault.

    ``magnitude`` is kind-specific: utilization delta (``capacity-crunch``),
    additive standard-normal shock (``price-spike``), or the fraction of
    resident spot VMs reclaimed (``storm``); unused for ``pool-outage``.
    ``pools`` is a tuple of pool ids, or None for *all* pools (the
    correlated case)."""
    kind: str
    t0: float
    duration: float = 0.0
    pools: Optional[Tuple[int, ...]] = None
    magnitude: float = 0.0

    @property
    def t1(self) -> float:
        return self.t0 + self.duration


def _validate_event(ev: FaultEvent, n_pools: int) -> None:
    if ev.kind not in FAULT_KINDS:
        raise ValueError(f"unknown fault kind {ev.kind!r} "
                         f"(known: {', '.join(FAULT_KINDS)})")
    if not ev.t0 >= 0.0:
        raise ValueError(f"fault t0 must be >= 0 (got {ev.t0!r})")
    if not ev.duration >= 0.0:
        raise ValueError(f"fault duration must be >= 0 (got {ev.duration!r})")
    if ev.pools is not None:
        bad = [p for p in ev.pools
               if not (isinstance(p, (int, np.integer)) and 0 <= p < n_pools)]
        if bad:
            raise ValueError(
                f"fault names unknown pool(s) {bad} "
                f"(known pools: 0..{n_pools - 1})")
    if ev.kind == "storm" and not (0.0 < ev.magnitude <= 1.0):
        raise ValueError(f"storm fraction must be in (0, 1] "
                         f"(got {ev.magnitude!r})")
    if ev.kind == "capacity-crunch" and not ev.magnitude > 0.0:
        raise ValueError(f"capacity-crunch utilization bias must be > 0 "
                         f"(got {ev.magnitude!r})")


class FaultInjector:
    """Holds a compiled, time-sorted fault schedule and answers the
    simulator's per-tick queries.  Stateful across one run (fired/ended
    flags) — use a fresh injector per simulation, like the engine."""

    #: event recorder — fault activations feed the flight log
    events_log = NULL_RECORDER

    def __init__(self, events: Sequence[FaultEvent], n_pools: int):
        evs = []
        for ev in events:
            if isinstance(ev, dict):
                ev = FaultEvent(**ev)
            _validate_event(ev, n_pools)
            if ev.pools is not None:
                ev = FaultEvent(ev.kind, float(ev.t0), float(ev.duration),
                                tuple(int(p) for p in ev.pools),
                                float(ev.magnitude))
            evs.append(ev)
        # deterministic schedule order regardless of generator order
        evs.sort(key=lambda e: (e.t0, FAULT_KINDS.index(e.kind),
                                e.pools or (), e.magnitude))
        self.events: Tuple[FaultEvent, ...] = tuple(evs)
        self.n_pools = int(n_pools)
        self._started = [False] * len(self.events)
        self._ended = [False] * len(self.events)

    # ------------------------------------------------------------- tick API
    def _pool_ids(self, ev: FaultEvent) -> Tuple[int, ...]:
        return ev.pools if ev.pools is not None else tuple(
            range(self.n_pools))

    def begin_tick(self, now: float) -> Tuple[List[Tuple[int, FaultEvent]],
                                              List[int]]:
        """Advance the schedule to ``now``.  Returns ``(started, ended)``:
        events newly *starting* this tick (index + event — storms fire once,
        outages deactivate their pool, window records go to metrics) and the
        indices of ``pool-outage`` events newly *ending* (reactivate)."""
        started: List[Tuple[int, FaultEvent]] = []
        ended: List[int] = []
        for i, ev in enumerate(self.events):
            if not self._started[i] and ev.t0 <= now + _EPS:
                self._started[i] = True
                started.append((i, ev))
                if self.events_log.enabled:
                    for p in self._pool_ids(ev):
                        self.events_log.emit(
                            now, "fault", pool=int(p),
                            a=float(ev.magnitude), b=float(ev.t1),
                            aux=ev.kind)
            if (self._started[i] and not self._ended[i]
                    and ev.kind == "pool-outage"
                    and now >= ev.t1 - _EPS and ev.t1 > ev.t0):
                self._ended[i] = True
                ended.append(i)
        return started, ended

    def _bias(self, now: float, kind: str) -> Optional[np.ndarray]:
        out = None
        for ev in self.events:
            if ev.kind != kind:
                continue
            if ev.t0 <= now + _EPS < ev.t1 + _EPS and now < ev.t1 - _EPS:
                if out is None:
                    out = np.zeros(self.n_pools)
                for p in self._pool_ids(ev):
                    out[p] += ev.magnitude
        return out

    def util_bias(self, now: float) -> Optional[np.ndarray]:
        """(n_pools,) utilization delta of the active capacity crunches at
        ``now`` (None when none are active — the engine's fast path)."""
        return self._bias(now, "capacity-crunch")

    def shock_bias(self, now: float) -> Optional[np.ndarray]:
        """(n_pools,) additive standard-normal shock of the active price
        spikes at ``now`` (None when none are active)."""
        return self._bias(now, "price-spike")

    def victims(self, registry: Dict[str, np.ndarray],
                ev: FaultEvent) -> np.ndarray:
        """Victim vm ids of storm ``ev`` against the live registry (see
        :func:`storm_victims`); the method keeps the simulator decoupled
        from this module's function layout."""
        return storm_victims(registry, self._pool_ids(ev), ev.magnitude)

    def pending(self) -> bool:
        """Any event still to fire?  Keeps a bounded run's PRICE_TICK chain
        alive through quiet spells before a scheduled fault."""
        return not all(self._started)


def storm_victims(registry: Dict[str, np.ndarray],
                  pools: Sequence[int], fraction: float) -> np.ndarray:
    """Victim VM ids of a correlated interruption storm: per affected pool,
    ``ceil(fraction * residents)`` running spot VMs, lowest bids first (the
    provider reclaims the least-paying capacity; vid breaks ties so the
    selection is deterministic).  One lexsort over the dense registry."""
    m = registry["vid"].size
    if m == 0:
        return np.zeros(0, dtype=np.int64)
    pool_col = registry["pool"]
    vids: List[np.ndarray] = []
    for p in pools:
        rows = np.flatnonzero(pool_col == p)
        if rows.size == 0:
            continue
        k = int(np.ceil(fraction * rows.size))
        order = np.lexsort((registry["vid"][rows], registry["bid"][rows]))
        vids.append(registry["vid"][rows[order[:k]]])
    if not vids:
        return np.zeros(0, dtype=np.int64)
    return np.concatenate(vids)


# ---------------------------------------------------------------------------
# built-in fault scenarios
# ---------------------------------------------------------------------------
@register_fault_scenario("scripted")
def _scripted(n_pools: int, horizon: float, tick_interval: float, seed: int,
              events: Sequence = ()) -> Tuple[FaultEvent, ...]:
    """Explicit event list (dicts or FaultEvents) — the fully scripted
    scenario; ``FaultSpec.events`` routes here."""
    return tuple(FaultEvent(**e) if isinstance(e, dict) else e
                 for e in events)


@register_fault_scenario("storm")
def _storm(n_pools: int, horizon: float, tick_interval: float, seed: int,
           first: float = 3600.0, every: float = 2400.0, count: int = 3,
           fraction: float = 0.5,
           pools: Optional[Sequence[int]] = None) -> Tuple[FaultEvent, ...]:
    """Correlated interruption storms: ``count`` storms starting at
    ``first``, spaced ``every`` seconds, each reclaiming ``fraction`` of
    the resident spot VMs in every affected pool at once."""
    pl = tuple(int(p) for p in pools) if pools is not None else None
    return tuple(FaultEvent("storm", first + k * every, 0.0, pl, fraction)
                 for k in range(int(count)))


@register_fault_scenario("random-storms")
def _random_storms(n_pools: int, horizon: float, tick_interval: float,
                   seed: int, rate_per_hour: float = 0.75,
                   fraction: float = 0.4) -> Tuple[FaultEvent, ...]:
    """Stochastic storms: Poisson arrivals over the horizon, whole schedule
    pre-drawn from the seed at construction (deterministic per seed)."""
    h = float(horizon) if horizon else 14400.0
    rng = np.random.default_rng([int(seed), 0xFA])
    n = int(rng.poisson(rate_per_hour * h / 3600.0))
    times = np.sort(rng.uniform(0.0, h, size=n))
    return tuple(FaultEvent("storm", float(t), 0.0, None, fraction)
                 for t in times)


@register_fault_scenario("pool-outage")
def _pool_outage(n_pools: int, horizon: float, tick_interval: float,
                 seed: int, pool: int = 0, start: float = 3600.0,
                 duration: float = 900.0) -> Tuple[FaultEvent, ...]:
    """One transient whole-pool outage: hosts down at ``start``, back at
    ``start + duration``."""
    return (FaultEvent("pool-outage", start, duration, (int(pool),)),)


@register_fault_scenario("price-spike")
def _price_spike(n_pools: int, horizon: float, tick_interval: float,
                 seed: int, start: float = 3600.0, duration: float = 600.0,
                 magnitude: float = 2.5,
                 pools: Optional[Sequence[int]] = None
                 ) -> Tuple[FaultEvent, ...]:
    """Shock-override price spike: ``magnitude`` standard deviations added
    to the affected pools' per-tick shocks for the window."""
    pl = tuple(int(p) for p in pools) if pools is not None else None
    return (FaultEvent("price-spike", start, duration, pl, magnitude),)


@register_fault_scenario("capacity-crunch")
def _capacity_crunch(n_pools: int, horizon: float, tick_interval: float,
                     seed: int, start: float = 3600.0,
                     duration: float = 1200.0, magnitude: float = 0.25,
                     pools: Optional[Sequence[int]] = None
                     ) -> Tuple[FaultEvent, ...]:
    """Utilization-bias capacity crunch: the demand signal feeding the
    clearing curve rises by ``magnitude`` for the window."""
    pl = tuple(int(p) for p in pools) if pools is not None else None
    return (FaultEvent("capacity-crunch", start, duration, pl, magnitude),)


def make_fault_injector(scenario: str, n_pools: int,
                        horizon: Optional[float], tick_interval: float,
                        seed: int, **params) -> FaultInjector:
    """Build an injector from a registered scenario name (``FaultSpec``'s
    builder entry point).  Unknown names fail fast with the known list."""
    events = FAULT_REGISTRY.get(scenario)(
        n_pools, horizon, tick_interval, seed, **params)
    return FaultInjector(events, n_pools)


#: causes the injector emits (re-exported for tests/docs)
FAULT_CAUSES = InterruptionCause.FAULT_CAUSES
