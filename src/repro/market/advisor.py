"""Synthetic AWS Spot-Instance-Advisor dataset (paper §VII-F).

The paper combines the Spot Advisor snapshot (interruption-frequency bands
<5 %, 5-10 %, 10-15 %, 15-20 %, >20 %), the spot price feed, and console
metadata into a 389-instance-type dataset, then measures which attributes
associate with interruption frequency (strongest: instance type 0.38, family
0.33, machine category 0.18).

Offline we generate a statistically similar dataset: interruption frequency is
drawn conditioned primarily on the exact *instance type* (strongest signal),
secondarily on *family*, weakly on *category* — so the correlation analysis
recovers the paper's ordering by construction, validating the pipeline.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

FREQ_BANDS = ["<5%", "5-10%", "10-15%", "15-20%", ">20%"]

_CATEGORIES = {
    "general": ["m5", "m5a", "m6i", "t3", "t3a"],
    "compute": ["c5", "c5a", "c6i", "c7g"],
    "memory": ["r5", "r5a", "r6i", "x2"],
    "accelerated": ["p3", "g4dn", "g5"],
    "storage": ["i3", "d3"],
}
_SIZES = ["large", "xlarge", "2xlarge", "4xlarge", "8xlarge"]
_REGIONS = ["us-east-1", "us-west-2", "eu-west-1"]
_OS = ["linux", "windows"]


def generate_advisor_dataset(n_rows: int = 1200, seed: int = 0) -> Dict[str, list]:
    """Columns: category, family, instance_type, region, os, vcpu, memory_gb,
    savings_pct, price_per_gb, gpu_count, day, free_tier, interruption_band."""
    rng = np.random.default_rng(seed)
    cats = list(_CATEGORIES.keys())

    # latent per-entity interruption propensities (the "ground truth" signal)
    fam_base: Dict[str, float] = {}
    type_base: Dict[str, float] = {}
    cat_base = {c: rng.uniform(0.3, 0.7) for c in cats}

    cols: Dict[str, list] = {k: [] for k in [
        "category", "family", "instance_type", "region", "os", "vcpu",
        "memory_gb", "savings_pct", "price_per_gb", "gpu_count", "day",
        "free_tier", "interruption_band"]}

    for _ in range(n_rows):
        cat = cats[rng.integers(len(cats))]
        fam = _CATEGORIES[cat][rng.integers(len(_CATEGORIES[cat]))]
        size = _SIZES[rng.integers(len(_SIZES))]
        itype = f"{fam}.{size}"
        if fam not in fam_base:
            fam_base[fam] = np.clip(cat_base[cat] + rng.normal(0, 0.22), 0, 1)
        if itype not in type_base:
            type_base[itype] = np.clip(fam_base[fam] + rng.normal(0, 0.3), 0, 1)

        vcpu = 2 ** (_SIZES.index(size) + 1)
        mem_mult = {"general": 4, "compute": 2, "memory": 8,
                    "accelerated": 4, "storage": 8}[cat]
        memory = vcpu * mem_mult
        gpu = int(rng.integers(1, 9)) if cat == "accelerated" else 0
        savings = float(np.clip(rng.normal(70, 12), 40, 90))
        price_gb = float(np.clip(rng.lognormal(-3.0, 0.4), 0.005, 0.5))

        # interruption propensity: dominated by exact type, plus band noise
        lam = 0.8 * type_base[itype] + 0.2 * rng.random()
        band = FREQ_BANDS[min(int(lam * len(FREQ_BANDS)), len(FREQ_BANDS) - 1)]

        cols["category"].append(cat)
        cols["family"].append(fam)
        cols["instance_type"].append(itype)
        cols["region"].append(_REGIONS[rng.integers(len(_REGIONS))])
        cols["os"].append(_OS[rng.integers(len(_OS))])
        cols["vcpu"].append(vcpu)
        cols["memory_gb"].append(memory)
        cols["savings_pct"].append(savings)
        cols["price_per_gb"].append(price_gb)
        cols["gpu_count"].append(gpu)
        cols["day"].append(int(rng.integers(7)))            # no signal (paper)
        cols["free_tier"].append(bool(rng.random() < 0.1))  # no signal (paper)
        cols["interruption_band"].append(band)

    for k in ("vcpu", "memory_gb", "savings_pct", "price_per_gb", "gpu_count",
              "day"):
        cols[k] = np.asarray(cols[k], dtype=np.float64)
    return cols


KINDS = {
    "category": "nominal", "family": "nominal", "instance_type": "nominal",
    "region": "nominal", "os": "nominal", "free_tier": "nominal",
    "interruption_band": "nominal",
    "vcpu": "numeric", "memory_gb": "numeric", "savings_pct": "numeric",
    "price_per_gb": "numeric", "gpu_count": "numeric", "day": "numeric",
}
