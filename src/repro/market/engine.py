"""Dynamic market engine: price-clearing over multi-pool spot markets.

This is the layer the paper's title promises — a *marketspace* where spot
prices move with supply/demand and trigger interruption, hibernation, and
reallocation — wired into :class:`repro.core.MarketSimulator` through
periodic PRICE_TICK events:

1. Each tick, every capacity pool's clearing price is drawn from its price
   process (``AuctionPrice`` / ``SmoothedPrice``, §II-B) fed with the pool's
   *live* CPU utilization (one ``bincount`` over the host arrays), optionally
   mixed with a shared demand shock (correlated-pool regime).  Policy choices
   feed back into prices: tighter packing → higher clearing prices.
2. Prices are pushed into the host pool (``set_pool_prices``): feasibility
   masks then require ``pool price <= vm.bid`` for spot admission, and price
   *drops* re-open queued spot VMs via the gain-log memo.
3. The simulator asks for the *interruption wave*: one masked comparison
   over the pool's dense spot registry (``market_victims``) selects every
   resident spot VM whose bid the new price crossed; victims route through
   the ordinary TERMINATE/HIBERNATE/resubmit lifecycle, so a hibernated
   victim can reallocate into a cheaper pool at a later flush.

The engine also integrates each pool's piecewise-constant price over time so
realized spot cost (billed at clearing price, not a flat discount) is exact:
see :func:`repro.market.pricing.realized_cost_stats`.

Engines are stateful (seeded price processes, cost integrals) — use a fresh
engine per simulation run.
"""
from __future__ import annotations

import bisect
from typing import List, Tuple

import numpy as np

from .pools import MarketConfig, PoolConfig
from .price_process import PRICE_PROCESS_REGISTRY


def _build_process(cfg: PoolConfig):
    """Resolve the pool's price process by name against
    :data:`~repro.market.price_process.PRICE_PROCESS_REGISTRY` (fails fast
    with the known names on a typo)."""
    return PRICE_PROCESS_REGISTRY.build(
        cfg.process, on_demand_rate=cfg.on_demand_rate, seed=cfg.seed,
        **dict(cfg.process_kwargs))


class MarketEngine:
    """Multi-pool price clearing + vectorized interruption waves."""

    def __init__(self, config: MarketConfig):
        self.config = config
        self.n_pools = len(config.pools)
        assert self.n_pools >= 1, "market needs at least one pool"
        self.tick_interval = float(config.tick_interval)
        self.processes = [_build_process(p) for p in config.pools]
        self.od_rates = np.array([p.on_demand_rate for p in config.pools])
        self._rng = np.random.default_rng(config.seed)
        #: AR(1) state of the shared demand shock (correlated regime):
        #: market-wide squeezes build and decay over several ticks instead
        #: of redrawing independently each tick
        self._shared_shock = 0.0
        self.prices = np.zeros(self.n_pools)
        # piecewise-constant price history: at tick k (time _ts[k]) pool i
        # clears at _price_hist[i][k]; _cum[i][k] = ∫_0^{_ts[k]} price dt
        self._ts: List[float] = []
        self._price_hist: List[List[float]] = [[] for _ in range(self.n_pools)]
        self._cum: List[List[float]] = [[] for _ in range(self.n_pools)]

    # ------------------------------------------------------------------ tick
    def tick(self, host_pool, now: float) -> np.ndarray:
        """Advance every pool's price process one step against live pool
        utilization; returns the new (n_pools,) clearing-price vector.  The
        caller (simulator) pushes the prices into the host pool and collects
        the wave."""
        util = host_pool.pool_cpu_utilization()
        if util.size < self.n_pools:
            util = np.concatenate(
                [util, np.zeros(self.n_pools - util.size)])
        if self.config.correlation > 0.0:
            rho = self.config.shock_rho
            innov = float(self._rng.normal(
                0.0, self.config.shock_sigma * np.sqrt(1.0 - rho ** 2)))
            self._shared_shock = rho * self._shared_shock + innov
            util = np.clip(
                util + self.config.correlation * self._shared_shock, 0.0, 1.0)
        # close the previous price segment in the integrals
        if self._ts:
            dt = now - self._ts[-1]
            for i in range(self.n_pools):
                self._cum[i].append(self._cum[i][-1]
                                    + self._price_hist[i][-1] * dt)
        else:
            for i in range(self.n_pools):
                self._cum[i].append(0.0)
        self._ts.append(now)
        for i in range(self.n_pools):
            p = float(self.processes[i].price(float(util[i])))
            self.prices[i] = p
            self._price_hist[i].append(p)
        return self.prices

    def price_of(self, pid: int) -> float:
        return float(self.prices[pid])

    # ------------------------------------------------------- realized pricing
    def price_integral(self, pid: int, t0: float, t1: float,
                       cap: float = float("inf")) -> float:
        """∫_{t0}^{t1} min(price_pid(t), cap) dt over the piecewise-constant
        clearing price (0 before the first tick; last price extends past the
        final tick).

        ``cap`` implements the bid contract — a spot VM never pays above its
        bid even while it rides out a price spike (minimum running time, or
        the interruption-warning window)."""
        if t1 <= t0 or not self._ts:
            return 0.0
        if cap == float("inf"):
            return self._integral_to(pid, t1) - self._integral_to(pid, t0)
        ts, ph = self._ts, self._price_hist[pid]
        i1 = bisect.bisect_right(ts, t1) - 1
        if i1 < 0:
            return 0.0
        i0 = bisect.bisect_right(ts, t0) - 1
        if i0 < 0:       # the span before the first tick prices at 0
            t0, i0 = ts[0], 0
            if t1 <= t0:
                return 0.0
        if i0 == i1:
            return min(ph[i0], cap) * (t1 - t0)
        total = min(ph[i0], cap) * (ts[i0 + 1] - t0)
        for k in range(i0 + 1, i1):
            total += min(ph[k], cap) * (ts[k + 1] - ts[k])
        total += min(ph[i1], cap) * (t1 - ts[i1])
        return total

    def _integral_to(self, pid: int, t: float) -> float:
        k = bisect.bisect_right(self._ts, t) - 1
        if k < 0:
            return 0.0
        return self._cum[pid][k] + self._price_hist[pid][k] * (t - self._ts[k])

    def discount_integral(self, pid: int, t0: float, t1: float,
                          cap: float = float("inf")) -> float:
        """∫ min(price, cap)/on_demand_rate dt — the time-integrated discount
        factor a spot VM realized while running in pool ``pid``."""
        return self.price_integral(pid, t0, t1, cap) / max(
            float(self.od_rates[pid]), 1e-12)

    # ------------------------------------------------------------- reporting
    def price_series(self, pid: int) -> Tuple[np.ndarray, np.ndarray]:
        """(tick times, clearing prices) of one pool."""
        return (np.asarray(self._ts), np.asarray(self._price_hist[pid]))
