"""Dynamic market engine: price-clearing over multi-pool spot markets.

This is the layer the paper's title promises — a *marketspace* where spot
prices move with supply/demand and trigger interruption, hibernation, and
reallocation — wired into :class:`repro.core.MarketSimulator` through
periodic PRICE_TICK events:

1. Each tick, every capacity pool's clearing price advances one step of its
   price process (``AuctionPrice`` / ``SmoothedPrice``, §II-B) fed with the
   pool's *live* CPU utilization (one ``bincount`` over the host arrays),
   optionally mixed with a shared demand shock (correlated-pool regime).
   Policy choices feed back into prices: tighter packing → higher clearing
   prices.
2. Prices are pushed into the host pool (``set_pool_prices``): feasibility
   masks then require ``pool price <= vm.bid`` for spot admission, and price
   *drops* re-open queued spot VMs via the gain-log memo.
3. The simulator asks for the *interruption wave*: one masked comparison
   over the pool's dense spot registry (``market_victims``) selects every
   resident spot VM whose bid the new price crossed; victims route through
   the ordinary TERMINATE/HIBERNATE/resubmit lifecycle, so a hibernated
   victim can reallocate into a cheaper pool at a later flush.

Array-native tick (PR 5): the engine pre-draws each pool's per-tick
standard-normal shock from per-pool streams (block-buffered, stream-exact)
and advances all pools of a process family in **one fused step call** over a
packed :data:`~repro.market.price_process.MarketState`
(``family.step(state, util_vec, shock_vec)``).  The per-pool scalar walk is
retained as the cross-validation oracle (``use_vectorized = False``, or
``MarketConfig.vectorized=False``): both paths consume the identical shock
vector and the identical kernels, so full-simulation metrics are
bit-identical — regression-tested in ``tests/market/test_price_vectorized``.

Price history lives in preallocated arrays (``tick_times()`` /
``price_history()`` views), so realized spot cost is a vectorized
``searchsorted`` + segment-sum: :meth:`MarketEngine.price_integrals` bills
an entire fleet of ``(pool, t0, t1, bid-cap)`` spans in one call (see
:func:`repro.market.pricing.realized_cost_stats`); the scalar
:meth:`price_integral` delegates to it, and the historical per-segment
``bisect`` walk survives as :func:`price_integral_ref` for the tests and
benchmarks.

Engines are stateful (seeded shock streams, price history) — use a fresh
engine per simulation run.
"""
from __future__ import annotations

import bisect
from typing import List, Optional, Tuple

import numpy as np

from .pools import MarketConfig, PoolConfig
from .price_process import (
    PRICE_PROCESS_REGISTRY,
    MarketState,
    ScalarProcessAdapter,
)
from ..obs.eventlog import NULL_RECORDER
from ..obs.tracer import NULL_TRACER

#: per-pool shock streams are drawn in blocks of this many ticks (one
#: ``standard_normal(block)`` call per pool per block — stream-identical to
#: per-tick scalar draws, amortizing the per-pool Python call overhead)
_SHOCK_BLOCK = 64

#: flat-element chunk of the batched capped-integral gather: bounds the
#: per-chunk scratch (a handful of `chunk`-sized temporaries) while keeping
#: numpy call overhead amortized at trace-scale batch sizes
_INTEGRAL_CHUNK_ELEMS = 1 << 20


def _build_process(cfg: PoolConfig):
    """Build the pool's *scalar* price process by name against
    :data:`~repro.market.price_process.PRICE_PROCESS_REGISTRY` (fails fast
    with the known names on a typo)."""
    return PRICE_PROCESS_REGISTRY.get(cfg.process).make_scalar(
        on_demand_rate=cfg.on_demand_rate, seed=cfg.seed,
        **dict(cfg.process_kwargs))


class MarketEngine:
    """Multi-pool price clearing + vectorized interruption waves."""

    def __init__(self, config: MarketConfig):
        self.config = config
        #: telemetry hooks (``repro.obs``); the build layer swaps in the
        #: live tracer / event recorder, instrumentation guards on
        #: ``tracer.enabled`` / ``events.enabled``
        self.tracer = NULL_TRACER
        self.events = NULL_RECORDER
        self.n_pools = len(config.pools)
        assert self.n_pools >= 1, "market needs at least one pool"
        self.tick_interval = float(config.tick_interval)
        self.processes = [_build_process(p) for p in config.pools]
        self.od_rates = np.array([p.on_demand_rate for p in config.pools],
                                 dtype=np.float64)
        self._rng = np.random.default_rng(config.seed)
        #: per-pool shock streams (identical seeds to the scalar processes,
        #: so oracle and vectorized paths consume the same randomness)
        self._pool_rngs = [np.random.default_rng(p.seed)
                           for p in config.pools]
        self._shock_block = np.zeros((0, self.n_pools), dtype=np.float64)
        self._shock_pos = 0
        #: fused family step (default) vs per-pool scalar oracle walk
        self.use_vectorized = bool(getattr(config, "vectorized", True))
        #: packed (family, pool-index, state) groups; built lazily at the
        #: first tick so tests may swap ``self.processes`` beforehand
        self._groups: Optional[List[list]] = None
        #: AR(1) state of the shared demand shock (correlated regime):
        #: market-wide squeezes build and decay over several ticks instead
        #: of redrawing independently each tick
        self._shared_shock = 0.0
        self.prices = np.zeros(self.n_pools, dtype=np.float64)
        #: last pool-utilization vector fed to the processes (risk fans
        #: project forward holding this demand signal)
        self.last_util = np.zeros(self.n_pools, dtype=np.float64)
        # piecewise-constant price history, preallocated: at tick k (time
        # tick_times()[k]) pool i clears at price_history()[i, k];
        # _cum_buf[i, k] = ∫_0^{ts[k]} price_i dt
        self._hist_cap = 256
        self._ts_buf = np.zeros(self._hist_cap, dtype=np.float64)
        self._ph_buf = np.zeros((self.n_pools, self._hist_cap), dtype=np.float64)
        self._cum_buf = np.zeros((self.n_pools, self._hist_cap), dtype=np.float64)
        self._n_ticks = 0

    # -------------------------------------------------------- packed groups
    def _build_groups(self) -> None:
        """Group ``self.processes`` by family and pack each group's state.
        Processes without an attached family (custom legacy processes,
        scripted test stubs) fall into per-group scalar-walk adapters."""
        order: List[Tuple[object, List[int]]] = []
        by_key = {}
        for i, proc in enumerate(self.processes):
            fam = getattr(type(proc), "family", None)
            if fam is not None:
                cls = getattr(fam, "scalar_cls", None)
                if (not getattr(fam, "vectorized", False)
                        or (cls is not None and type(proc) is not cls)):
                    # subclasses inherit the `family` attribute but may
                    # override price() — only the exact scalar class is
                    # guaranteed to match the packed kernel; anything else
                    # walks scalar so overrides are honored
                    fam = None
            key = id(fam) if fam is not None else None
            if key in by_key:
                by_key[key][1].append(i)
            else:
                ent = (fam, [i])
                by_key[key] = ent
                order.append(ent)
        self._groups = []
        for fam, idx in order:
            procs = [self.processes[i] for i in idx]
            if fam is None:
                # reuse the registry's legacy-protocol adapter as the
                # fallback walk (factory unused — the group wraps the
                # already-built live objects)
                fam = ScalarProcessAdapter("scalar-walk", None)
            state = fam.pack(procs)
            self._groups.append([fam, np.asarray(idx, dtype=np.int64),
                                 state])

    def price_state(self):
        """Snapshot of the packed per-family price state:
        ``[(family, pool_indices, state), ...]`` with copied leaves — the
        input for offline projections (``risk.simulated_price_fan``)."""
        if self._groups is None or not self.use_vectorized:
            # scalar-oracle mode evolves the per-pool objects, not the
            # packed group state — re-pack from the live processes so the
            # snapshot reflects the current tick in either mode
            self._build_groups()
        out = []
        for fam, idx, state in self._groups:
            leaves = {k: (v.copy() if isinstance(v, np.ndarray) else v)
                      for k, v in state.items()}
            out.append((fam, idx.copy(), leaves))
        return out

    # ------------------------------------------------------------------ tick
    def _draw_shocks(self) -> np.ndarray:
        """(n_pools,) standard-normal shock vector, one per pool per tick,
        from the per-pool streams (block-buffered; stream-exact)."""
        if self._shock_pos >= self._shock_block.shape[0]:
            self._shock_block = np.stack(
                [g.standard_normal(_SHOCK_BLOCK) for g in self._pool_rngs],
                axis=1) if self.n_pools else np.zeros((_SHOCK_BLOCK, 0),
                                                      dtype=np.float64)
            self._shock_pos = 0
        z = self._shock_block[self._shock_pos]
        self._shock_pos += 1
        return z

    def tick(self, host_pool, now: float, util_bias=None,
             shock_bias=None) -> np.ndarray:
        """Advance every pool's price process one step against live pool
        utilization; returns the new (n_pools,) clearing-price vector.  The
        caller (simulator) pushes the prices into the host pool and collects
        the wave.

        ``util_bias`` / ``shock_bias`` are optional (n_pools,) additive
        biases from the fault-injection layer (``market/faults``): a
        capacity crunch raises the demand signal *before* the clearing
        curve, a price spike raises the tick's standard-normal shocks —
        either way the faults flow through the normal price processes.
        ``None`` (the default) is bit-identical to the unbiased tick."""
        util = host_pool.pool_cpu_utilization()
        if util.size < self.n_pools:
            util = np.concatenate(
                [util, np.zeros(self.n_pools - util.size, dtype=np.float64)])
        if self.config.correlation > 0.0:
            rho = self.config.shock_rho
            innov = float(self._rng.normal(
                0.0, self.config.shock_sigma * np.sqrt(1.0 - rho ** 2)))
            self._shared_shock = rho * self._shared_shock + innov
            util = np.clip(
                util + self.config.correlation * self._shared_shock, 0.0, 1.0)
        if util_bias is not None:
            util = np.clip(util + util_bias, 0.0, 1.0)
        self.last_util = util
        z = self._draw_shocks()
        if shock_bias is not None:
            z = z + shock_bias
        # close the previous price segment in the integrals
        k = self._n_ticks
        if k + 1 > self._hist_cap:
            self._grow_history(k + 1)
        if k:
            dt = now - self._ts_buf[k - 1]
            np.multiply(self._ph_buf[:, k - 1], dt, out=self._cum_buf[:, k])
            self._cum_buf[:, k] += self._cum_buf[:, k - 1]
        else:
            self._cum_buf[:, 0] = 0.0
        self._ts_buf[k] = now
        if self._groups is None:
            self._build_groups()
        tr = self.tracer
        traced = tr.enabled
        if traced:
            tr.begin("market-engine",
                     "engine/families" if self.use_vectorized
                     else "engine/scalar-walk")
        if self.use_vectorized:
            for g in self._groups:
                fam, idx, state = g
                state, p = fam.step(state, util[idx], z[idx])
                g[2] = state
                self.prices[idx] = p
        else:
            # scalar oracle walk: identical shocks, identical kernels
            for i, proc in enumerate(self.processes):
                if getattr(proc, "shock_protocol", False):
                    p = proc.price(float(util[i]), shock=float(z[i]))
                else:
                    p = proc.price(float(util[i]))
                self.prices[i] = p
        if traced:
            tr.end(now, None)
        self._ph_buf[:, k] = self.prices
        self._n_ticks = k + 1
        if self.events.enabled:
            # one flight-recorder record per pool per tick — the price
            # series the post-hoc risk analytics reconstruct from the log
            for pid in range(self.n_pools):
                self.events.emit(now, "price-tick", pool=pid,
                                 a=float(self.prices[pid]))
        return self.prices

    def _grow_history(self, need: int) -> None:
        cap = max(need, self._hist_cap * 2)
        ts = np.zeros(cap, dtype=np.float64)
        ts[: self._n_ticks] = self._ts_buf[: self._n_ticks]
        ph = np.zeros((self.n_pools, cap), dtype=np.float64)
        ph[:, : self._n_ticks] = self._ph_buf[:, : self._n_ticks]
        cum = np.zeros((self.n_pools, cap), dtype=np.float64)
        cum[:, : self._n_ticks] = self._cum_buf[:, : self._n_ticks]
        self._ts_buf, self._ph_buf, self._cum_buf = ts, ph, cum
        self._hist_cap = cap

    def price_of(self, pid: int) -> float:
        return float(self.prices[pid])

    # ------------------------------------------------------- history views
    @property
    def n_ticks(self) -> int:
        return self._n_ticks

    def tick_times(self) -> np.ndarray:
        """(n_ticks,) tick timestamps (read-only view)."""
        v = self._ts_buf[: self._n_ticks]
        v.flags.writeable = False    # the buffer backs billing — no writes
        return v

    def price_history(self) -> np.ndarray:
        """(n_pools, n_ticks) clearing prices (read-only view)."""
        v = self._ph_buf[:, : self._n_ticks]
        v.flags.writeable = False
        return v

    # ------------------------------------------------------- realized pricing
    def price_integrals(self, pids, t0s, t1s, caps=None) -> np.ndarray:
        """Batched ∫_{t0}^{t1} min(price_pid(t), cap) dt over the
        piecewise-constant clearing prices — the whole fleet's billing in
        one vectorized call (0 before the first tick; the last price
        extends past the final tick).

        ``caps`` implements the bid contract — a spot VM never pays above
        its bid even while it rides out a price spike (minimum running
        time, or the interruption-warning window); ``None`` = uncapped."""
        pids = np.asarray(pids, dtype=np.int64)
        t0s = np.asarray(t0s, dtype=np.float64)
        t1s = np.asarray(t1s, dtype=np.float64)
        b = pids.size
        if self.tracer.enabled:
            self.tracer.counters.inc("billing/calls")
            self.tracer.counters.inc("billing/spans", int(b))
        out = np.zeros(b, dtype=np.float64)
        k = self._n_ticks
        if b == 0 or k == 0:
            return out
        caps = (np.full(b, np.inf, dtype=np.float64) if caps is None
                else np.asarray(caps, dtype=np.float64))
        ts = self._ts_buf[:k]
        finite = np.isfinite(caps)
        if not finite.all():
            sel = np.flatnonzero(~finite)
            out[sel] = self._uncapped(pids[sel], t0s[sel], t1s[sel])
        if finite.any():
            sel = np.flatnonzero(finite)
            ph = self._ph_buf
            ts_next = np.empty(k, dtype=np.float64)
            ts_next[:-1] = ts[1:]
            ts_next[-1] = np.inf
            # each query only touches the segments its span overlaps
            # (segment j runs [ts[j], ts[j+1]); the last extends to ∞, and
            # t < ts[0] prices at 0 by construction) — gather exactly
            # those (query, segment) pairs CSR-style, so work and memory
            # scale with Σ touched segments, not queries × n_ticks, and
            # each row's reduction is independent of the rest of the batch
            # (scalar B=1 billing stays exactly equal to fleet-batched)
            j0 = np.maximum(
                np.searchsorted(ts, t0s[sel], side="right") - 1, 0)
            j1 = np.minimum(np.searchsorted(ts, t1s[sel], side="left"), k)
            lens = np.maximum(j1 - j0, 0)
            starts = np.zeros(sel.size + 1, dtype=np.int64)
            np.cumsum(lens, out=starts[1:])
            # chunk over queries so the flat gather stays memory-bounded
            lo = 0
            while lo < sel.size:
                hi = int(np.searchsorted(
                    starts, starts[lo] + _INTEGRAL_CHUNK_ELEMS,
                    side="left"))
                hi = min(max(hi, lo + 1), sel.size)
                total = int(starts[hi] - starts[lo])
                if total == 0:
                    lo = hi
                    continue
                lens_c = lens[lo:hi]
                base = starts[lo:hi] - starts[lo]
                rows = np.repeat(np.arange(lo, hi, dtype=np.int64), lens_c)
                col = (np.repeat(j0[lo:hi], lens_c)
                       + np.arange(total, dtype=np.int64)
                       - np.repeat(base, lens_c))
                q = sel[rows]
                p = np.minimum(ph[pids[q], col], caps[q])
                over = (np.minimum(ts_next[col], t1s[q])
                        - np.maximum(ts[col], t0s[q]))
                np.clip(over, 0.0, None, out=over)
                p *= over
                nz = np.flatnonzero(lens_c)
                out[sel[lo + nz]] = np.add.reduceat(p, base[nz])
                lo = hi
        return out

    def _uncapped(self, pids, t0s, t1s) -> np.ndarray:
        """Uncapped batched integrals via searchsorted + the cumulative
        per-pool price integral (O(log k) per query)."""
        k = self._n_ticks
        ts = self._ts_buf[:k]

        def at(t):
            idx = np.searchsorted(ts, t, side="right") - 1
            safe = np.maximum(idx, 0)
            val = (self._cum_buf[pids, safe]
                   + self._ph_buf[pids, safe] * (t - ts[safe]))
            return np.where(idx >= 0, val, 0.0)

        return np.where(t1s > t0s, at(t1s) - at(t0s), 0.0)

    def price_integral(self, pid: int, t0: float, t1: float,
                       cap: float = float("inf")) -> float:
        """Scalar ∫ min(price, cap) dt — delegates to the batched kernel,
        so scalar and fleet-batched billing agree exactly."""
        if t1 <= t0 or self._n_ticks == 0:
            return 0.0
        return float(self.price_integrals(
            np.asarray([pid], dtype=np.int64),
            np.asarray([t0], dtype=np.float64),
            np.asarray([t1], dtype=np.float64),
            np.asarray([cap], dtype=np.float64))[0])

    def discount_integrals(self, pids, t0s, t1s, caps=None) -> np.ndarray:
        """Batched ∫ min(price, cap)/on_demand_rate dt — the fleet's
        time-integrated discount factors in one call."""
        pids = np.asarray(pids, dtype=np.int64)
        return self.price_integrals(pids, t0s, t1s, caps) / np.maximum(
            self.od_rates[pids], 1e-12)

    def discount_integral(self, pid: int, t0: float, t1: float,
                          cap: float = float("inf")) -> float:
        """∫ min(price, cap)/on_demand_rate dt — the time-integrated discount
        factor a spot VM realized while running in pool ``pid``."""
        return self.price_integral(pid, t0, t1, cap) / max(
            float(self.od_rates[pid]), 1e-12)

    # ------------------------------------------------------------- reporting
    def price_series(self, pid: int) -> Tuple[np.ndarray, np.ndarray]:
        """(tick times, clearing prices) of one pool."""
        return (self.tick_times().copy(), self.price_history()[pid].copy())


def price_integral_ref(engine: MarketEngine, pid: int, t0: float, t1: float,
                       cap: float = float("inf")) -> float:
    """The historical per-segment ``bisect`` integral — retained verbatim as
    the reference the vectorized :meth:`MarketEngine.price_integrals` is
    regression-tested (and benchmarked) against."""
    if t1 <= t0 or engine.n_ticks == 0:
        return 0.0
    ts = engine.tick_times().tolist()
    ph = engine.price_history()[pid].tolist()
    cum = engine._cum_buf[pid, : engine.n_ticks].tolist()
    if cap == float("inf"):
        def integral_to(t: float) -> float:
            k = bisect.bisect_right(ts, t) - 1
            if k < 0:
                return 0.0
            return cum[k] + ph[k] * (t - ts[k])
        return integral_to(t1) - integral_to(t0)
    i1 = bisect.bisect_right(ts, t1) - 1
    if i1 < 0:
        return 0.0
    i0 = bisect.bisect_right(ts, t0) - 1
    if i0 < 0:       # the span before the first tick prices at 0
        t0, i0 = ts[0], 0
        if t1 <= t0:
            return 0.0
    if i0 == i1:
        return min(ph[i0], cap) * (t1 - t0)
    total = min(ph[i0], cap) * (ts[i0 + 1] - t0)
    for k in range(i0 + 1, i1):
        total += min(ph[k], cap) * (ts[k + 1] - ts[k])
    total += min(ph[i1], cap) * (t1 - ts[i1])
    return total
