"""Google-Cluster-Trace-style workloads (paper §VII-C/D).

The 2011 Google trace has MACHINE EVENTS (add/remove/update) and TASK EVENTS;
the paper groups tasks into synthetic VMs by (user, machine) and injects
200 k spot instances with fixed 20/40 h durations on top of the trace load.

We provide:
* ``generate_trace``  — a scaled synthetic trace with the structural features
  the paper relies on: a machine fleet with heterogeneous capacity, machine
  add/remove churn, diurnal task arrival (paper Figs. 7–9), and task resource
  requests; fully seeded.
* ``write_trace_csv`` / ``load_trace`` — the CSV interchange format
  (machine_events.csv, task_events.csv) so real trace extracts can be fed in.
* ``simulate_trace``  — drives a :class:`MarketSimulator` from a trace plus
  injected spot instances, reproducing the §VII-D experiment at configurable
  scale.
"""
from __future__ import annotations

import csv
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.allocation import AllocationPolicy, FirstFit
from ..core.simulator import MarketSimulator, SimConfig
from ..core.types import InterruptionBehavior, make_on_demand, make_spot, resources


@dataclass
class TraceConfig:
    seed: int = 0
    n_machines: int = 400
    sim_days: float = 1.0
    # mean concurrently-active VMs per machine (trace: ~100k active / 12.6k mach)
    load_per_machine: float = 16.0
    machine_churn_per_day: float = 0.02   # fraction removed/re-added per day
    n_spot: int = 2_000                   # paper: 200k at full scale
    spot_durations_h: Tuple[float, float] = (20.0, 40.0)
    hibernation_timeout_s: float = 4 * 3600.0
    min_running_time_s: float = 60.0
    spot_behavior: InterruptionBehavior = InterruptionBehavior.HIBERNATE


@dataclass
class Trace:
    # (time_s, machine_id, event['add'|'remove'|'update'], cpu, ram, bw, storage)
    machine_events: List[tuple] = field(default_factory=list)
    # (time_s, vm_id, cpu, ram, bw, storage, duration_s, kind['od'|'spot'])
    task_events: List[tuple] = field(default_factory=list)


# Machine platform mix loosely following the trace's capacity distribution
# (normalized units; the trace normalizes CPU/RAM to the largest machine).
_MACHINE_TYPES = [
    (0.50, resources(16, 24_576, 10_000, 400_000)),
    (0.31, resources(32, 49_152, 10_000, 400_000)),
    (0.19, resources(64, 98_304, 20_000, 800_000)),
]


def _diurnal_rate(t_s: float, base: float) -> float:
    """Arrival intensity with the trace's day/night swing (paper Fig. 9)."""
    hour = (t_s / 3600.0) % 24.0
    return base * (1.0 + 0.35 * np.sin((hour - 6.0) / 24.0 * 2 * np.pi))


def generate_trace(cfg: TraceConfig | None = None) -> Trace:
    cfg = cfg or TraceConfig()
    rng = np.random.default_rng(cfg.seed)
    horizon = cfg.sim_days * 86_400.0
    tr = Trace()

    probs = np.array([p for p, _ in _MACHINE_TYPES])
    caps = [c for _, c in _MACHINE_TYPES]
    for mid in range(cfg.n_machines):
        cap = caps[rng.choice(len(caps), p=probs)]
        tr.machine_events.append((0.0, mid, "add", *cap))
    # churn: remove + re-add a fraction of machines at random times
    n_churn = int(cfg.machine_churn_per_day * cfg.n_machines * cfg.sim_days)
    for _ in range(n_churn):
        mid = int(rng.integers(cfg.n_machines))
        t_rm = float(rng.uniform(0.1, 0.8) * horizon)
        t_re = t_rm + float(rng.uniform(600.0, 7200.0))
        tr.machine_events.append((t_rm, mid, "remove", 0, 0, 0, 0))
        cap = caps[rng.choice(len(caps), p=probs)]
        if t_re < horizon:
            tr.machine_events.append((t_re, mid, "add", *cap))

    # --- VM (grouped-task) arrivals: Poisson with diurnal modulation --------
    # target: load_per_machine concurrent VMs/machine; mean duration ~1h ->
    # arrival rate = target_active / mean_duration
    mean_dur = 3600.0
    target_active = cfg.load_per_machine * cfg.n_machines
    base_rate = target_active / mean_dur  # arrivals per second
    t, vm_id = 0.0, 0
    while t < horizon:
        rate = _diurnal_rate(t, base_rate)
        t += float(rng.exponential(1.0 / max(rate, 1e-9)))
        if t >= horizon:
            break
        cpu = float(rng.choice([0.5, 1, 2, 4, 8], p=[0.35, 0.3, 0.2, 0.1, 0.05]))
        ram = cpu * float(rng.uniform(1_024, 2_048))
        dur = float(rng.lognormal(mean=np.log(mean_dur) - 0.5, sigma=1.0))
        dur = min(max(dur, 30.0), horizon)
        tr.task_events.append((t, vm_id, cpu, ram, 10.0, 1_000.0, dur, "od"))
        vm_id += 1

    # --- injected spot instances (paper §VII-D: 200k @ 20/40 h) -------------
    for k in range(cfg.n_spot):
        t0 = float(rng.uniform(0.0, 0.25 * horizon))
        dur_h = cfg.spot_durations_h[k % 2]
        cpu = float(rng.choice([1, 2, 4]))
        tr.task_events.append(
            (t0, vm_id, cpu, cpu * 1_536.0, 10.0, 1_000.0, dur_h * 3600.0, "spot"))
        vm_id += 1

    tr.task_events.sort(key=lambda e: e[0])
    return tr


# -- CSV interchange ----------------------------------------------------------
def write_trace_csv(tr: Trace, directory: str) -> None:
    os.makedirs(directory, exist_ok=True)
    with open(os.path.join(directory, "machine_events.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["time", "machine_id", "event", "cpu", "ram", "bw", "storage"])
        w.writerows(tr.machine_events)
    with open(os.path.join(directory, "task_events.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["time", "vm_id", "cpu", "ram", "bw", "storage",
                    "duration", "kind"])
        w.writerows(tr.task_events)


def load_trace(directory: str) -> Trace:
    tr = Trace()
    with open(os.path.join(directory, "machine_events.csv")) as f:
        for row in csv.DictReader(f):
            tr.machine_events.append((
                float(row["time"]), int(row["machine_id"]), row["event"],
                float(row["cpu"]), float(row["ram"]), float(row["bw"]),
                float(row["storage"])))
    with open(os.path.join(directory, "task_events.csv")) as f:
        for row in csv.DictReader(f):
            tr.task_events.append((
                float(row["time"]), int(row["vm_id"]), float(row["cpu"]),
                float(row["ram"]), float(row["bw"]), float(row["storage"]),
                float(row["duration"]), row["kind"]))
    tr.task_events.sort(key=lambda e: e[0])
    return tr


# -- trace-driven simulation --------------------------------------------------
def wire_trace(sim: MarketSimulator, tr: Trace,
               cfg: TraceConfig | None = None) -> MarketSimulator:
    """Populate an (empty) simulator from a trace: t=0 machines become hosts,
    later machine events become scheduled host add/remove/update, task events
    become submitted VMs.  Shared by :func:`simulate_trace` and the scenario
    API's ``trace`` workload, so both wire bit-identically."""
    cfg = cfg or TraceConfig()
    # machine id -> host id mapping (machines can be re-added)
    m2h: Dict[int, int] = {}
    for (t, mid, event, cpu, ram, bw, st) in sorted(tr.machine_events):
        if event == "add":
            if t == 0.0 and mid not in m2h:
                m2h[mid] = sim.add_host(resources(cpu, ram, bw, st))
            else:
                # re-adds map to fresh host slots (trace semantics: new machine)
                sim.schedule_host_add(t, resources(cpu, ram, bw, st))
        elif event == "remove" and mid in m2h:
            sim.schedule_host_remove(t, m2h[mid])
        elif event == "update" and mid in m2h:
            sim.schedule_host_update(t, m2h[mid], resources(cpu, ram, bw, st))

    for (t, vid, cpu, ram, bw, st, dur, kind) in tr.task_events:
        demand = resources(cpu, ram, bw, st)
        if kind == "spot":
            vm = make_spot(
                vid, demand, dur, behavior=cfg.spot_behavior,
                min_running_time=cfg.min_running_time_s,
                hibernation_timeout=cfg.hibernation_timeout_s,
                waiting_timeout=float("inf"), submit_time=t)
        else:
            vm = make_on_demand(vid, demand, dur, waiting_timeout=3600.0,
                                submit_time=t)
        sim.submit(vm)
    return sim


def simulate_trace(
    tr: Trace,
    policy: Optional[AllocationPolicy] = None,
    cfg: TraceConfig | None = None,
    sim_config: Optional[SimConfig] = None,
    until: Optional[float] = None,
    engine=None,
    migration=None,
    rebid=None,
    obs=None,
    events=None,
):
    """Run the market simulator on a trace. Returns (simulator, metrics).
    ``engine`` / ``migration`` / ``rebid`` / ``obs`` / ``events`` pass
    through to :class:`MarketSimulator` (all default off — the paper's
    §VII-D setup)."""
    cfg = cfg or TraceConfig()
    sim = MarketSimulator(
        policy=policy or FirstFit(),
        config=sim_config or SimConfig(record_timeline=False),
        engine=engine, migration=migration, rebid=rebid, obs=obs,
        events=events,
    )
    if obs is not None and obs.enabled:
        sim.policy.tracer = obs
        if engine is not None:
            engine.tracer = obs
        if migration is not None:
            migration.tracer = obs
    if events is not None and events.enabled:
        if engine is not None:
            engine.events = events
        if migration is not None:
            migration.events = events
    wire_trace(sim, tr, cfg)
    metrics = sim.run(until=until)
    return sim, metrics
