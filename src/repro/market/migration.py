"""Proactive cross-pool migration & rebalancing (beyond-paper subsystem).

The paper's finding is that allocation policy choice reduces spot
interruption counts and maximum interruption duration — but a reactive
simulator only moves victims *after* a price wave hits.  This planner runs
on every PRICE_TICK and moves resident spot VMs *ahead* of price spikes
(Voorsluys & Buyya: proactive movement dominates reactive fault tolerance):

1. Every RUNNING spot VM is scored in **one dense masked numpy pass over the
   host pool's market registry** (no per-VM Python walk): for each candidate
   destination pool, ``net = (p̂_src − p̂_dst) · W − downtime · delay_cost``
   where ``p̂`` is the policy's price basis, ``W = min(remaining_work,
   horizon)`` is the savings window, and the downtime term monetizes the
   stop-and-copy delay.
2. Hysteresis: a move needs a price gap above ``hysteresis`` *and* a
   positive net score; an arrived VM is blacked out for ``cooldown`` seconds
   (stamped into the registry), so an oscillating price cannot flap a VM
   A→B→A between consecutive ticks.
3. The selected moves are emitted as :class:`MigrationPlan`s; the simulator
   executes each through a MIGRATE_START → MIGRATE_COMPLETE event pair with
   destination capacity *reserved* for the flight and downtime accounted in
   :class:`repro.core.metrics.Metrics`.

Policies:

* ``none``            — planner inert; the simulation is bit-identical to a
                        run without a planner attached.
* ``greedy-cheapest`` — score against *current* clearing prices and chase
                        any pool that is cheaper right now (pure cost
                        chaser; churny under noisy prices).
* ``gradient-aware``  — score against regression-projected prices
                        (:func:`repro.market.risk.projected_prices`) and
                        move only *at-risk* VMs — those whose projected
                        source price comes within ``danger_margin`` of the
                        bid.  Safe VMs stay put: every migration raises the
                        destination's utilization (and hence its clearing
                        price), so churning safe VMs manufactures the very
                        waves the planner exists to dodge.  Destinations are
                        assigned *price-impact-aware* (each committed
                        arrival shifts the destination's effective price by
                        the clearing curve's slope — evacuation is
                        self-limiting) and throttled per tick.
* ``risk-budgeted``   — gradient-aware scoring plus a per-pool cap on
                        concurrent arrivals (in-flight + newly planned), so
                        the planner's own herd cannot drive a destination
                        pool's utilization — and hence its clearing price —
                        into a spike.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from . import risk
from ..core.registry import Registry
from ..obs.eventlog import NULL_RECORDER
from ..obs.tracer import NULL_TRACER
from .price_process import supply_curve_slope

MIGRATION_POLICIES = ("none", "greedy-cheapest", "gradient-aware",
                      "risk-budgeted")

#: string-keyed registry of migration policies; ``make_migration_planner``
#: and ``MigrationSpec`` resolve against it.  The four built-ins map to
#: :class:`MigrationPlanner` configured with the matching
#: :class:`MigrationConfig` policy; custom entries may register any factory
#: returning a planner-shaped object (``.config.policy``,
#: ``.plan(pool, engine, now, inflight)``):
#: ``@register_migration_policy("my-policy")``.
MIGRATION_REGISTRY = Registry("migration policy")
register_migration_policy = MIGRATION_REGISTRY.register


@dataclass
class MigrationConfig:
    policy: str = "gradient-aware"
    #: stop-and-copy downtime per migration (s); no progress accrues in flight
    downtime: float = 30.0
    #: per-VM blackout after an arrival — the flap guard
    cooldown: float = 300.0
    #: required price gap (price units) in the policy's basis before a move
    #: is even considered — the hysteresis margin
    hysteresis: float = 0.08
    #: savings window cap (s): price projections are not trusted further out
    horizon: float = 600.0
    #: VMs with less remaining work than this never move (the downtime would
    #: eat the savings; also keeps nearly-done VMs off the wire)
    min_remaining: float = 60.0
    #: price-units-per-second monetization of migration delay
    delay_cost_rate: float = 0.5
    #: gradient-aware / risk-budgeted only: a VM is migration-eligible when
    #: its projected source price comes within this margin of its bid (or
    #: exceeds it) — the defensive trigger; greedy-cheapest ignores it
    danger_margin: float = 0.15
    #: ticks of history feeding the gradient estimate
    gradient_window: int = 5
    #: arrivals are only planned into pools below this CPU utilization: the
    #: clearing curve is convex in utilization, so landing migrants on a
    #: busy pool raises the price for every resident there (the externality
    #: the net score cannot see)
    dest_util_ceiling: float = 0.65
    #: gradient-aware / risk-budgeted: global throttle on plans per tick —
    #: evacuation trickles over several ticks instead of moving a whole
    #: pool's population in one thundering herd
    max_plans_per_tick: int = 32
    #: risk-budgeted only: max concurrent arrivals per destination pool
    pool_budget: int = 4

    def __post_init__(self) -> None:
        assert self.policy in MIGRATION_POLICIES, (
            f"unknown migration policy {self.policy!r} "
            f"(want {MIGRATION_POLICIES})")


@dataclass
class MigrationPlan:
    """One planned move: VM → destination pool.  The simulator picks the
    concrete destination host at MIGRATE_START (capacity may shift between
    planning and execution within the same timestamp)."""
    vm_id: int
    dst_pool: int
    predicted_saving: float     # net score, price·seconds


class MigrationPlanner:
    """Scores the market registry each tick and emits batched plans."""

    #: telemetry hooks (``repro.obs``); the build layer swaps in the live
    #: tracer/recorder — class attributes so planner construction stays
    #: untouched
    tracer = NULL_TRACER
    events = NULL_RECORDER

    def __init__(self, config: MigrationConfig | None = None):
        self.config = config or MigrationConfig()

    # ------------------------------------------------------------------ plan
    def _price_basis(self, engine) -> np.ndarray:
        cfg = self.config
        if cfg.policy == "greedy-cheapest":
            return engine.prices.copy()
        # gradient-aware / risk-budgeted: project to the arrival time of a
        # migration started this tick.  The regression fit reads the
        # engine's packed price-history arrays directly (zero-copy views —
        # see risk.recent_prices), so this stays cheap on the tick path.
        lead = cfg.downtime + engine.tick_interval
        return risk.projected_prices(engine, lead, cfg.gradient_window)

    def plan(self, host_pool, engine, now: float,
             inflight_per_pool: np.ndarray) -> List[MigrationPlan]:
        """One dense masked scoring pass over the registry screens the
        at-risk candidates; a short commit loop (selected candidates only)
        assigns destinations *price-impact-aware*: every committed arrival
        shifts the destination's effective price by the clearing curve's
        slope, so the planner's own herd prices itself out of a destination
        before it can spike it.  Fully deterministic, no RNG."""
        tr = self.tracer
        if not (tr.enabled or self.events.enabled):
            return self._plan_impl(host_pool, engine, now, inflight_per_pool)
        if tr.enabled:
            tr.begin("migration", "plan/" + self.config.policy)
        plans = self._plan_impl(host_pool, engine, now, inflight_per_pool)
        if tr.enabled:
            if plans:
                tr.counters.inc("migrations/planned", len(plans))
            tr.end(now, {"plans": len(plans)})
        if self.events.enabled:
            for p in plans:
                self.events.emit(now, "migrate-plan", vm=p.vm_id,
                                 pool=p.dst_pool, a=p.predicted_saving)
        return plans

    def _plan_impl(self, host_pool, engine, now: float,
                   inflight_per_pool: np.ndarray) -> List[MigrationPlan]:
        cfg = self.config
        if cfg.policy == "none":
            return []
        reg = host_pool.market_registry()
        m = reg["vid"].size
        if m == 0:
            return []
        n_pools = engine.n_pools
        prices = engine.prices
        p_hat = self._price_basis(engine)
        free_cpu = host_pool.pool_free_cpu()
        util = host_pool.pool_cpu_utilization()

        rem_now = reg["rem0"] - (now - reg["t0"])
        elig = (reg["pin"] < 0)                   # pool-pinned VMs never move
        elig &= reg["cooldown"] <= now            # flap guard
        elig &= reg["ready"] <= now               # respect min running time
        elig &= rem_now > cfg.min_remaining
        if cfg.policy != "greedy-cheapest":
            # defensive trigger: only evacuate VMs whose projected source
            # price approaches their bid
            elig &= p_hat[reg["pool"]] > reg["bid"] - cfg.danger_margin
        if not elig.any():
            return []

        # compress the registry to the eligible rows, then build the
        # (m_elig, n_pools) static net score in the policy's price basis —
        # the screening pass (impact-free; the commit loop re-prices).
        # At fleet scale the danger trigger eliminates most rows, so the
        # dense matrices only span the candidates.
        rows = np.flatnonzero(elig)
        src = reg["pool"][rows]
        bid = reg["bid"][rows]
        cpu = reg["cpu"][rows]
        vid = reg["vid"][rows]
        gap = p_hat[src][:, None] - p_hat[None, :]
        W = np.minimum(rem_now[rows], cfg.horizon)
        net = gap * W[:, None] - cfg.downtime * cfg.delay_cost_rate

        ok = gap > cfg.hysteresis                          # margin on the gap
        ok &= prices[None, :] <= bid[:, None] - cfg.hysteresis
        ok &= p_hat[None, :] <= bid[:, None] - cfg.hysteresis
        # destination headroom: the pool must have free CPU for this VM now
        # and sit below the utilization ceiling (price-impact guard)
        ok &= free_cpu[None, :] >= cpu[:, None]
        ok &= (util <= cfg.dest_util_ceiling)[None, :]
        ok &= np.arange(n_pools)[None, :] != src[:, None]  # actually move
        net = np.where(ok, net, -np.inf)

        best0 = net.max(axis=1)
        sel = np.flatnonzero(best0 > 0.0)
        if sel.size == 0:
            return []
        # deterministic commit order: biggest static saving first
        order = sel[np.lexsort((vid[sel], -best0[sel]))]

        if cfg.policy == "greedy-cheapest":
            # the naive chaser: commits every screened move at face value
            # (no impact model, no throttle) — the herding baseline
            best_q = np.argmax(net, axis=1)
            return [MigrationPlan(int(vid[i]), int(best_q[i]),
                                  float(best0[i]))
                    for i in order]
        return self._commit_with_impact(host_pool, engine, order,
                                        src, bid, cpu, vid, W,
                                        prices, p_hat, free_cpu, util,
                                        inflight_per_pool)

    def _commit_with_impact(self, host_pool, engine, order,
                            src_a, bid_a, cpu_a, vid_a, W, prices,
                            p_hat, free_cpu, util, inflight_per_pool):
        """Assign destinations with the arrivals committed so far priced in:
        ``p_eff = p̂ + (∂price/∂cpu) · committed Δcpu`` per pool, where the
        slope comes from the clearing curve (d/du of od·(0.1+0.9u³)).
        Departures lower the source's effective price the same way, so
        evacuation is self-limiting.  O(selected × n_pools) — the registry
        itself is never walked."""
        cfg = self.config
        n_pools = engine.n_pools
        # ∂price/∂cpu at current utilization (convex curve: busy pools are
        # expensive to land on, idle pools nearly free)
        pool_cpu = np.maximum(host_pool.pool_total_cpu(), 1e-9)
        impact = supply_curve_slope(util, engine.od_rates) / pool_cpu
        delta_cpu = np.zeros(n_pools)
        free = free_cpu.astype(np.float64).copy()
        util_eff = util.copy()
        budget = None
        if cfg.policy == "risk-budgeted":
            budget = cfg.pool_budget - np.asarray(
                inflight_per_pool, dtype=np.int64).copy()
        plans: List[MigrationPlan] = []
        pool_ids = np.arange(n_pools)
        # hard work bound for the tick hot path: candidates arrive in
        # descending static-saving order, so if the head can't commit the
        # tail won't either — never scan more than 4x the plan cap
        scan_budget = 4 * cfg.max_plans_per_tick
        for i in order:
            if len(plans) >= cfg.max_plans_per_tick or scan_budget <= 0:
                break
            if budget is not None and not (budget > 0).any():
                break  # every destination's arrival budget is exhausted
            scan_budget -= 1
            s = int(src_a[i])
            bid = float(bid_a[i])
            cpu = float(cpu_a[i])
            p_eff = p_hat + impact * delta_cpu
            gap = p_eff[s] - p_eff
            net = gap * float(W[i]) - cfg.downtime * cfg.delay_cost_rate
            ok = gap > cfg.hysteresis
            ok &= prices <= bid - cfg.hysteresis
            ok &= p_eff <= bid - cfg.hysteresis
            ok &= free >= cpu
            ok &= util_eff <= cfg.dest_util_ceiling
            ok &= pool_ids != s
            if budget is not None:
                ok &= budget > 0
            net = np.where(ok, net, -np.inf)
            q = int(np.argmax(net))
            if net[q] <= 0.0:
                continue
            plans.append(MigrationPlan(int(vid_a[i]), q,
                                       float(net[q])))
            delta_cpu[q] += cpu
            delta_cpu[s] -= cpu
            free[q] -= cpu
            free[s] += cpu
            # plain division, matching plan_reference bit-for-bit (a
            # reciprocal-multiply differs in the last ULP and could flip
            # the util-ceiling comparison between planner and oracle)
            util_eff[q] += cpu / pool_cpu[q]
            util_eff[s] -= cpu / pool_cpu[s]
            if budget is not None:
                budget[q] -= 1
        return plans


# ---------------------------------------------------------------------------
# per-VM reference oracle (tests + benchmark: the planner must match this
# while never walking the registry in Python on the tick path)
# ---------------------------------------------------------------------------
def plan_reference(planner: MigrationPlanner, host_pool, engine, now: float,
                   inflight_per_pool: np.ndarray) -> List[MigrationPlan]:
    """Decision-identical per-VM Python reimplementation of
    :meth:`MigrationPlanner.plan` (scalar screening + scalar commit loop)."""
    cfg = planner.config
    if cfg.policy == "none":
        return []
    reg = host_pool.market_registry()
    m = reg["vid"].size
    n_pools = engine.n_pools
    prices = engine.prices
    p_hat = planner._price_basis(engine)
    free_cpu = host_pool.pool_free_cpu()
    util = host_pool.pool_cpu_utilization()

    def static_screen(i):
        """(best static net, best pool) for VM i, or (None, -1)."""
        rem_now = float(reg["rem0"][i]) - (now - float(reg["t0"][i]))
        if (reg["pin"][i] >= 0 or reg["cooldown"][i] > now
                or reg["ready"][i] > now or rem_now <= cfg.min_remaining):
            return None, -1, 0.0
        src = int(reg["pool"][i])
        bid = float(reg["bid"][i])
        if (cfg.policy != "greedy-cheapest"
                and not p_hat[src] > bid - cfg.danger_margin):
            return None, -1, 0.0
        w = min(rem_now, cfg.horizon)
        best_q, best = -1, -np.inf
        for q in range(n_pools):
            if q == src:
                continue
            gap = float(p_hat[src] - p_hat[q])
            if gap <= cfg.hysteresis:
                continue
            if prices[q] > bid - cfg.hysteresis or p_hat[q] > bid - cfg.hysteresis:
                continue
            if free_cpu[q] < reg["cpu"][i] or util[q] > cfg.dest_util_ceiling:
                continue
            net = gap * w - cfg.downtime * cfg.delay_cost_rate
            if net > best:
                best_q, best = q, net
        if best_q < 0 or best <= 0.0:
            return None, -1, 0.0
        return best, best_q, w

    scored = []
    for i in range(m):
        best, best_q, w = static_screen(i)
        if best is not None:
            scored.append((best, int(reg["vid"][i]), i, best_q, w))
    scored.sort(key=lambda s: (-s[0], s[1]))

    if cfg.policy == "greedy-cheapest":
        return [MigrationPlan(vid, q, float(net))
                for net, vid, _i, q, _w in scored]

    pool_cpu = np.maximum(host_pool.pool_total_cpu(), 1e-9)
    impact = supply_curve_slope(util, engine.od_rates) / pool_cpu
    delta_cpu = np.zeros(n_pools)
    free = free_cpu.astype(np.float64).copy()
    util_eff = util.copy()
    budget = ({q: cfg.pool_budget - int(inflight_per_pool[q])
               for q in range(n_pools)}
              if cfg.policy == "risk-budgeted" else None)
    plans = []
    scan_budget = 4 * cfg.max_plans_per_tick
    for _net0, vid, i, _q0, w in scored:
        if len(plans) >= cfg.max_plans_per_tick or scan_budget <= 0:
            break
        if budget is not None and not any(b > 0 for b in budget.values()):
            break
        scan_budget -= 1
        src = int(reg["pool"][i])
        bid = float(reg["bid"][i])
        cpu = float(reg["cpu"][i])
        p_eff = p_hat + impact * delta_cpu
        best_q, best = -1, -np.inf
        for q in range(n_pools):
            if q == src:
                continue
            gap = float(p_eff[src] - p_eff[q])
            if gap <= cfg.hysteresis:
                continue
            if prices[q] > bid - cfg.hysteresis or p_eff[q] > bid - cfg.hysteresis:
                continue
            if free[q] < cpu or util_eff[q] > cfg.dest_util_ceiling:
                continue
            if budget is not None and budget[q] <= 0:
                continue
            net = gap * w - cfg.downtime * cfg.delay_cost_rate
            if net > best:
                best_q, best = q, net
        if best_q < 0 or best <= 0.0:
            continue
        plans.append(MigrationPlan(vid, best_q, float(best)))
        delta_cpu[best_q] += cpu
        delta_cpu[src] -= cpu
        free[best_q] -= cpu
        free[src] += cpu
        util_eff[best_q] += cpu / pool_cpu[best_q]
        util_eff[src] -= cpu / pool_cpu[src]
        if budget is not None:
            budget[best_q] -= 1
    return plans


def _builtin_planner(policy: str):
    def _factory(**kwargs) -> MigrationPlanner:
        return MigrationPlanner(MigrationConfig(policy=policy, **kwargs))
    _factory.__name__ = f"planner_{policy}"
    return _factory


for _policy in MIGRATION_POLICIES:
    MIGRATION_REGISTRY.register(_policy, _builtin_planner(_policy))
del _policy


def make_migration_planner(policy: str, **kwargs) -> MigrationPlanner:
    """Build a planner by policy name (including ``"none"``, which attaches
    but never plans — the bit-identity baseline)."""
    return MIGRATION_REGISTRY.build(policy, **kwargs)
