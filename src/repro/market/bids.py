"""Bid strategies for spot VMs in the dynamic market engine.

A bid is the maximum clearing price a spot VM pays: the engine interrupts it
whenever its pool's price exceeds the bid, and admission masks only open
hosts whose pool currently clears at <= bid.  Strategies follow the
bid-price provisioning line of Voorsluys et al. and the price-volatility-
aware randomized strategies of Bhuyan et al.:

* :class:`OnDemandCapBid`   — bid a fixed fraction of the on-demand rate;
  fraction 1.0 caps at on-demand (never price-interrupted, pays up to full
  rate), lower fractions trade interruption risk for a hard cost ceiling.
* :class:`PercentileBid`    — bid the p-th percentile of a reference price
  history (the classic "bid above the historical spike floor" heuristic).
* :class:`RandomizedBid`    — per-VM bid drawn uniformly from
  ``[lo, hi] × on-demand`` (Bhuyan et al.: randomizing bids across a fleet
  de-synchronizes interruption waves, so one price spike does not take out
  every VM at once).

All draws are seeded; :func:`assign_bids` stamps ``vm.bid`` in place for the
spot VMs of a workload so identical workloads get identical bids across
policies (the paper's §VII-E2 same-randomized-values methodology).

:class:`RebidOnResume` is the *adaptive* follow-up (Bhuyan et al., optimal
randomized restart strategies): when a spot VM is interrupted into
hibernation, its bid is bumped by a seeded randomized factor (capped at the
on-demand rate) before resubmission — survival improves after each
interruption instead of replaying the same losing bid.  Off by default; wire
it via ``MarketSimulator(rebid=...)``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Optional

import numpy as np

from ..core.registry import Registry
from ..core.types import Vm
from .pools import PoolConfig
from .engine import _build_process

#: string-keyed registry of bid strategies; ``make_bid_strategy`` and
#: ``BidSpec`` resolve against it — register custom strategies with
#: ``@register_bid_strategy("my-strategy")`` (any callable whose instances
#: expose ``bids(n, rng) -> np.ndarray``).
BID_REGISTRY = Registry("bid strategy")
register_bid_strategy = BID_REGISTRY.register


@register_bid_strategy("on-demand-cap")
@dataclass
class OnDemandCapBid:
    name = "on-demand-cap"
    fraction: float = 1.0
    on_demand_rate: float = 1.0

    def bids(self, n: int, rng: np.random.Generator) -> np.ndarray:
        return np.full(n, self.fraction * self.on_demand_rate)


@register_bid_strategy("percentile")
@dataclass
class PercentileBid:
    name = "percentile"
    pct: float = 90.0
    history: Optional[np.ndarray] = None   # reference price series

    def bids(self, n: int, rng: np.random.Generator) -> np.ndarray:
        hist = self.history
        assert hist is not None and len(hist) > 0, (
            "PercentileBid needs a reference price history "
            "(see reference_history)")
        return np.full(n, float(np.percentile(np.asarray(hist), self.pct)))


@register_bid_strategy("randomized")
@dataclass
class RandomizedBid:
    name = "randomized"
    lo: float = 0.35
    hi: float = 1.0
    on_demand_rate: float = 1.0

    def bids(self, n: int, rng: np.random.Generator) -> np.ndarray:
        return rng.uniform(self.lo, self.hi, n) * self.on_demand_rate


@dataclass
class RebidOnResume:
    """Seeded randomized bid bump on hibernation (the resubmit path).

    The draw is keyed on ``(seed, vm id, interruption count)`` — independent
    of event interleaving, so two identical runs re-bid identically and a
    VM's k-th interruption always draws the same factor.  The new bid is
    ``min(bid × U[bump_lo, bump_hi], on_demand_rate)``: monotone
    non-decreasing, hard-capped at the market ceiling."""

    bump_lo: float = 1.05
    bump_hi: float = 1.30
    on_demand_rate: float = 1.0
    seed: int = 0

    def rebid(self, vm: Vm) -> float:
        rng = np.random.default_rng([self.seed, vm.id, vm.interruptions])
        bump = float(rng.uniform(self.bump_lo, self.bump_hi))
        return float(min(vm.bid * bump, self.on_demand_rate))


def reference_history(pool_cfg: PoolConfig, n: int = 720,
                      seed: int = 0) -> np.ndarray:
    """Synthetic price history for percentile bidding: a fresh copy of the
    pool's price process driven by a seeded mean-reverting utilization path
    (what an operator would estimate from past market data)."""
    proc = _build_process(PoolConfig(
        pool_cfg.name, process=pool_cfg.process,
        on_demand_rate=pool_cfg.on_demand_rate, seed=seed + 7919,
        process_kwargs=dict(pool_cfg.process_kwargs)))
    rng = np.random.default_rng(seed)
    u, out = 0.6, []
    for t in range(n):
        diurnal = 0.15 * np.sin(2 * np.pi * t / 288.0)
        u += 0.05 * (0.6 + diurnal - u) + 0.03 * rng.normal()
        out.append(proc.price(min(max(u, 0.05), 0.99)))
    return np.asarray(out)


def make_bid_strategy(name: str, pool_cfg: Optional[PoolConfig] = None,
                      seed: int = 0, **kwargs):
    """Build a strategy by name.  When ``pool_cfg`` is given it supplies the
    defaults the strategy scales against: the on-demand rate for the cap /
    randomized strategies (so fraction 1.0 really caps at the market's
    ceiling) and the reference price history for ``percentile``."""
    if pool_cfg is not None and "on_demand_rate" not in kwargs \
            and name in ("on-demand-cap", "randomized"):
        kwargs["on_demand_rate"] = pool_cfg.on_demand_rate
    if name == "percentile" and "history" not in kwargs:
        assert pool_cfg is not None, "percentile needs pool_cfg or history"
        kwargs["history"] = reference_history(pool_cfg, seed=seed)
    return BID_REGISTRY.build(name, **kwargs)


def assign_bids(vms: Iterable[Vm], strategy, seed: int = 0) -> List[Vm]:
    """Stamp ``vm.bid`` on every *spot* VM (on-demand VMs keep bid=inf).
    Draws are ordered by the iteration order of ``vms``, so a fixed seed +
    fixed workload yields identical bids across policy runs."""
    spot = [v for v in vms if v.is_spot]
    rng = np.random.default_rng(seed)
    bids = strategy.bids(len(spot), rng)
    for v, b in zip(spot, bids):
        v.bid = float(b)
    return spot
