"""Cost accounting for simulated marketspaces (beyond-paper extension).

The paper motivates spot instances by their up-to-90 % discounts (§II-B) and
frames the contribution as insight into "cost–performance trade-offs within
volatile cloud markets" (§III), but does not quantify cost. This module
prices each VM's execution history with an on-demand rate model (linear in
resources, AWS-like coefficients) and a configurable spot discount, yielding
per-policy cost/savings/waste metrics:

* ``cost``        — Σ interval_duration × rate(demand) × (discount if spot)
* ``od_equiv``    — the same execution billed at on-demand rates
* ``wasted_cost`` — spend on work that was lost (TERMINATED spot VMs pay for
  their partial execution but deliver nothing — the hidden price of
  interruptions that hibernation avoids)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable

import numpy as np

from ..core.types import Vm, VmState, VmType


@dataclass(frozen=True)
class PriceModel:
    """$ per resource-hour (AWS-like: CPU-dominated, memory secondary)."""
    per_cpu_hour: float = 0.0425        # ~m5 on-demand per vCPU
    per_gb_ram_hour: float = 0.0057
    per_gbps_bw_hour: float = 0.01
    per_tb_storage_hour: float = 0.05
    spot_discount: float = 0.30         # spot pays 30% of on-demand (70% off)

    def rate(self, demand: np.ndarray) -> float:
        """on-demand $/hour for a resource vector (cpu, ram MB, bw Mbps,
        storage MB)."""
        cpu, ram, bw, st = (float(x) for x in demand)
        return (cpu * self.per_cpu_hour
                + ram / 1024.0 * self.per_gb_ram_hour
                + bw / 1000.0 * self.per_gbps_bw_hour
                + st / 1_048_576.0 * self.per_tb_storage_hour)

    def vm_cost(self, vm: Vm) -> float:
        hours = sum((i.stop - i.start) for i in vm.history
                    if i.stop is not None) / 3600.0
        rate = self.rate(vm.demand)
        if vm.vm_type is VmType.SPOT:
            rate *= self.spot_discount
        return hours * rate

    def vm_od_equivalent(self, vm: Vm) -> float:
        hours = sum((i.stop - i.start) for i in vm.history
                    if i.stop is not None) / 3600.0
        return hours * self.rate(vm.demand)


def realized_cost_stats(vms: Iterable[Vm], engine, host_pool,
                        model: PriceModel | None = None) -> Dict[str, float]:
    """Cost accounting against the market engine's *realized* price series:
    spot VMs are billed each execution interval at their pool's clearing
    price (piecewise-constant between PRICE_TICKs), not a flat discount.

    ``engine`` is the :class:`repro.market.engine.MarketEngine` that ran the
    simulation (it holds the per-pool price integrals); ``host_pool`` maps
    each interval's host to its capacity pool.  The billed price is capped
    at the VM's bid — a spot VM riding out a spike above its bid (minimum
    running time, or an interruption-warning window) pays its bid, never
    the clearing price, honoring the bid contract.  On-demand VMs bill at
    the flat on-demand rate, exactly as in :func:`cost_stats`.

    The whole fleet's price integrals are computed in **one** batched
    :meth:`~repro.market.engine.MarketEngine.discount_integrals` call (one
    ``(pool, start, stop, bid-cap)`` row per closed execution interval);
    the remaining Python loop only accumulates the per-VM sums, in the same
    order as the historical per-VM walk.
    """
    model = model or PriceModel()
    tr = engine.tracer
    if tr.enabled:
        tr.begin("billing", "realized_cost")
    total = od_equiv = wasted = spot_cost = 0.0
    pool_of = host_pool.pool_of
    vm_list = list(vms)
    # gather every closed spot execution interval for one batched call
    pids: list = []
    t0s: list = []
    t1s: list = []
    caps: list = []
    for vm in vm_list:
        if vm.vm_type is not VmType.SPOT:
            continue
        for itv in vm.history:
            if itv.stop is None:
                continue
            pids.append(int(pool_of[itv.host]))
            t0s.append(itv.start)
            t1s.append(itv.stop)
            caps.append(vm.bid)
    discounts = engine.discount_integrals(
        np.asarray(pids, dtype=np.int64), np.asarray(t0s),
        np.asarray(t1s), np.asarray(caps))
    cursor = 0
    for vm in vm_list:
        rate = model.rate(vm.demand)
        od_c = model.vm_od_equivalent(vm)
        od_equiv += od_c
        if vm.vm_type is not VmType.SPOT:
            total += od_c
            continue
        c = 0.0
        for itv in vm.history:
            if itv.stop is None:
                continue
            c += rate / 3600.0 * float(discounts[cursor])
            cursor += 1
        total += c
        spot_cost += c
        if vm.state is VmState.TERMINATED:
            wasted += c
    if tr.enabled:
        # post-run call: stamp with the last tick time, not a live clock
        sim_t = float(engine.tick_times()[-1]) if engine.n_ticks else 0.0
        tr.end(sim_t, {"intervals": len(pids)})
    return {
        "cost": total,
        "od_equivalent": od_equiv,
        "savings": od_equiv - total,
        "savings_pct": 100.0 * (od_equiv - total) / max(od_equiv, 1e-12),
        "spot_cost": spot_cost,
        "wasted_cost": wasted,
    }


def cost_stats(vms: Iterable[Vm],
               model: PriceModel | None = None) -> Dict[str, float]:
    model = model or PriceModel()
    total = od_equiv = wasted = spot_cost = 0.0
    for vm in vms:
        c = model.vm_cost(vm)
        total += c
        od_equiv += model.vm_od_equivalent(vm)
        if vm.vm_type is VmType.SPOT:
            spot_cost += c
            if vm.state is VmState.TERMINATED:
                wasted += c     # paid for partial work, delivered nothing
    return {
        "cost": total,
        "od_equivalent": od_equiv,
        "savings": od_equiv - total,
        "savings_pct": 100.0 * (od_equiv - total) / max(od_equiv, 1e-12),
        "spot_cost": spot_cost,
        "wasted_cost": wasted,
    }
