"""Mixed-type association measures (paper §VII-F).

The paper uses the ``dython.nominal`` library: Theil's U for nominal-nominal
pairs, the correlation ratio (eta) for numeric-categorical, and Pearson for
numeric-numeric.  Re-implemented here in numpy (no external deps).
"""
from __future__ import annotations

import math
from collections import Counter
from typing import Dict, List, Sequence

import numpy as np


def _entropy(labels: Sequence) -> float:
    n = len(labels)
    if n == 0:
        return 0.0
    counts = Counter(labels)
    return -sum((c / n) * math.log(c / n) for c in counts.values())


def conditional_entropy(x: Sequence, y: Sequence) -> float:
    """H(X|Y)."""
    n = len(x)
    if n == 0:
        return 0.0
    y_counts = Counter(y)
    xy_counts = Counter(zip(x, y))
    h = 0.0
    for (xv, yv), c_xy in xy_counts.items():
        p_xy = c_xy / n
        p_y = y_counts[yv] / n
        h -= p_xy * math.log(p_xy / p_y)
    return h


def theils_u(x: Sequence, y: Sequence) -> float:
    """Theil's uncertainty coefficient U(X|Y) in [0, 1] (asymmetric)."""
    h_x = _entropy(x)
    if h_x == 0.0:
        return 1.0
    return (h_x - conditional_entropy(x, y)) / h_x


def correlation_ratio(categories: Sequence, values: np.ndarray) -> float:
    """eta: numeric-categorical association in [0, 1]."""
    values = np.asarray(values, dtype=np.float64)
    cats: Dict = {}
    for c, v in zip(categories, values):
        cats.setdefault(c, []).append(v)
    mean_all = values.mean()
    ss_between = sum(len(v) * (np.mean(v) - mean_all) ** 2 for v in cats.values())
    ss_total = ((values - mean_all) ** 2).sum()
    if ss_total <= 0:
        return 0.0
    return float(np.sqrt(ss_between / ss_total))


def pearson(x: np.ndarray, y: np.ndarray) -> float:
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    sx, sy = x.std(), y.std()
    if sx <= 0 or sy <= 0:
        return 0.0
    return float(((x - x.mean()) * (y - y.mean())).mean() / (sx * sy))


def association_matrix(
    columns: Dict[str, Sequence],
    kinds: Dict[str, str],   # name -> 'nominal' | 'numeric'
) -> Dict[str, Dict[str, float]]:
    """Pairwise association with dython-style measure selection.

    nominal-nominal  -> Theil's U (row given column),
    numeric-nominal  -> correlation ratio,
    numeric-numeric  -> |Pearson|.
    """
    names = list(columns.keys())
    out: Dict[str, Dict[str, float]] = {n: {} for n in names}
    for a in names:
        for b in names:
            if a == b:
                out[a][b] = 1.0
                continue
            ka, kb = kinds[a], kinds[b]
            if ka == "nominal" and kb == "nominal":
                v = theils_u(columns[a], columns[b])
            elif ka == "nominal" and kb == "numeric":
                v = correlation_ratio(columns[a], columns[b])
            elif ka == "numeric" and kb == "nominal":
                v = correlation_ratio(columns[b], columns[a])
            else:
                v = abs(pearson(columns[a], columns[b]))
            out[a][b] = float(v)
    return out
