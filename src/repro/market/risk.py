"""Pool-level risk signals for proactive spot migration.

Two consumers:

* The :class:`repro.market.migration.MigrationPlanner` projects near-future
  clearing prices from the engine's tick history via
  :func:`projected_prices` (Voorsluys & Buyya: acting ahead of a price
  spike dominates purely reactive fault tolerance).
  :func:`price_gradients`, :func:`price_volatility`, and
  :func:`bid_crossing_risk` expose the underlying signals for risk-aware
  extensions (e.g. a probabilistic danger trigger, or risk-aware admission
  — see the ROADMAP follow-up).
* :func:`advisor_pool_volatility` derives per-pool price-process volatility
  from the synthetic Spot-Instance-Advisor dataset (§VII-F interruption-
  frequency bands), so ``pools.make_market`` regimes can be grounded in the
  advisor data instead of hand-set constants.

Everything here is a dense vectorized computation over the engine's price
history — these functions run inside the PRICE_TICK hot path.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from .advisor import FREQ_BANDS, generate_advisor_dataset

# ---------------------------------------------------------------------------
# price history signals (engine = repro.market.engine.MarketEngine)
# ---------------------------------------------------------------------------


def recent_prices(engine, window: int) -> np.ndarray:
    """(n_pools, k) matrix of the last ``k <= window`` tick prices (k >= 1;
    a single zero column before the first tick).  A read-only view into the
    engine's packed price-history arrays — no per-call copy."""
    n = engine.n_ticks
    if n == 0:
        return np.zeros((engine.n_pools, 1))
    k = min(window, n)
    return engine.price_history()[:, n - k:]


def _price_fit(engine, window: int):
    """Shared least-squares machinery: (slopes, window means, centered-time
    offset of the last tick).  Slopes are zero before two ticks exist."""
    ts = engine.tick_times()
    k = min(window, ts.size)
    if k < 2:
        p = recent_prices(engine, max(k, 1))
        return np.zeros(engine.n_pools), p.mean(axis=1), 0.0
    t = ts[-k:]
    p = recent_prices(engine, k)                 # (n_pools, k)
    t_mean = t.mean()
    tc = t - t_mean
    var = float(np.dot(tc, tc))
    means = p.mean(axis=1)
    if var <= 0.0:
        return np.zeros(engine.n_pools), means, 0.0
    slopes = (p - means[:, None]) @ tc / var
    return slopes, means, float(ts[-1] - t_mean)


def price_gradients(engine, window: int = 5) -> np.ndarray:
    """(n_pools,) least-squares slope (price per second) of each pool's
    clearing price over the last ``window`` ticks — one vectorized solve
    across all pools.  Zero before two ticks exist."""
    return _price_fit(engine, window)[0]


def price_volatility(engine, window: int = 12) -> np.ndarray:
    """(n_pools,) standard deviation of the last ``window`` tick prices —
    the planner's noise scale for bid-crossing risk."""
    return recent_prices(engine, window).std(axis=1)


def projected_prices(engine, lead: float, window: int = 5) -> np.ndarray:
    """(n_pools,) clearing prices ``lead`` seconds past the last tick, read
    off each pool's least-squares regression line (value *and* slope from
    the fit — evaluating the line rather than extrapolating from the last
    sample filters the heavy-tailed per-tick shock the auction regime
    draws), clipped to [0, on-demand rate]."""
    slopes, means, dt_last = _price_fit(engine, window)
    proj = means + slopes * (dt_last + lead)
    return np.clip(proj, 0.0, engine.od_rates)


def bid_crossing_risk(projected: np.ndarray, sigma: np.ndarray,
                      bids: np.ndarray, pools: np.ndarray) -> np.ndarray:
    """Per-VM probability-like score that the VM's pool price crosses its bid
    around the projection point: a logistic squash of
    ``(projected_price - bid) / sigma``.  Vectorized over the registry
    (``bids``/``pools`` are per-VM, ``projected``/``sigma`` per-pool)."""
    s = np.maximum(sigma[pools], 1e-6)
    z = (projected[pools] - bids) / s
    return 1.0 / (1.0 + np.exp(-np.clip(z, -30.0, 30.0)))


def simulated_price_fan(engine, n_ticks: int, n_paths: int = 64,
                        seed: int = 0, quantiles=(0.1, 0.5, 0.9),
                        util=None, backend: str = "numpy") -> np.ndarray:
    """Monte-Carlo price fan: simulate ``n_paths`` shock trajectories
    ``n_ticks`` forward from the engine's *current* packed price state and
    return per-pool price quantiles — a distributional complement to the
    point projection of :func:`projected_prices`.

    Returns ``(len(quantiles), n_ticks, n_pools)``.  The demand signal is
    held at ``util`` (default: the engine's last observed pool utilization);
    shocks are drawn from a fresh ``default_rng(seed)`` (the engine's own
    streams are not disturbed).  ``backend="jax"`` runs each family's
    simulation as one ``jax.lax.scan``; pools of adapter-wrapped legacy
    processes are excluded from the fan (their column holds the last
    clearing price — their draws are private to the live objects).
    """
    from .price_process import simulate_price_paths

    assert n_ticks >= 1 and n_paths >= 1
    util = np.asarray(engine.last_util if util is None else util,
                      dtype=np.float64)
    rng = np.random.default_rng(seed)
    paths = np.broadcast_to(
        engine.prices[None, None, :],
        (n_ticks, n_paths, engine.n_pools)).copy()
    for fam, idx, state in engine.price_state():
        if not getattr(fam, "vectorized", False):
            continue
        shocks = rng.standard_normal((n_ticks, n_paths, idx.size))
        prices, _ = simulate_price_paths(
            fam, state, np.broadcast_to(util[idx], (n_ticks, idx.size)),
            shocks, backend=backend)
        paths[:, :, idx] = prices
    return np.quantile(paths, np.asarray(quantiles), axis=1)


# ---------------------------------------------------------------------------
# Spot-Advisor interruption-frequency bands -> pool volatility
# ---------------------------------------------------------------------------

#: midpoint interruption frequency of each advisor band (the ">20%" band is
#: open-ended; 0.25 is the conventional working point)
BAND_RATES: Dict[str, float] = {
    "<5%": 0.025, "5-10%": 0.075, "10-15%": 0.125, "15-20%": 0.175,
    ">20%": 0.25,
}
assert set(BAND_RATES) == set(FREQ_BANDS), "advisor band set drifted"

#: calibration anchors mapping mean interruption frequency to the price
#: process' shock sigma: the calmest band maps near the smoothed-regime
#: noise floor, the most volatile band past the volatile preset's 0.45
_FREQ_ANCHORS = (0.025, 0.25)
_SIGMA_ANCHORS = (0.12, 0.60)


def frequency_to_sigma(freq: np.ndarray) -> np.ndarray:
    """Map mean interruption frequency (0..1) to a price-process shock sigma
    by linear interpolation between the calibration anchors."""
    return np.interp(np.asarray(freq, dtype=np.float64),
                     _FREQ_ANCHORS, _SIGMA_ANCHORS)


def advisor_pool_volatility(n_pools: int, seed: int = 0,
                            n_rows: int = 1200) -> np.ndarray:
    """(n_pools,) per-pool shock sigmas derived from the synthetic advisor
    dataset.

    The paper's §VII-F association analysis finds *instance family* among
    the strongest predictors of interruption frequency, so a capacity pool
    (one instance class) inherits its families' volatility: families are
    ranked by their mean interruption-band frequency and partitioned into
    ``n_pools`` contiguous groups — pool 0 gets the calmest families, pool
    ``n_pools-1`` the spikiest — then each pool's mean frequency maps
    through :func:`frequency_to_sigma`.  This preserves the heterogeneity
    the advisor data actually shows (round-robin mixing would average it
    away).  Fully seeded — identical across runs."""
    assert n_pools >= 1
    data = generate_advisor_dataset(n_rows=n_rows, seed=seed)
    rates = np.array([BAND_RATES[b] for b in data["interruption_band"]])
    fam_rate: Dict[str, list] = {}
    for f, r in zip(data["family"], rates):
        fam_rate.setdefault(f, []).append(r)
    # rank families calm -> spiky (name tiebreak keeps this deterministic)
    ranked = sorted(fam_rate, key=lambda f: (float(np.mean(fam_rate[f])), f))
    groups = np.array_split(np.arange(len(ranked)), n_pools)
    fam_pool = {ranked[i]: p for p, g in enumerate(groups) for i in g}
    pools = np.array([fam_pool[f] for f in data["family"]], dtype=np.int64)
    sums = np.bincount(pools, weights=rates, minlength=n_pools)
    counts = np.bincount(pools, minlength=n_pools)
    overall = rates.mean()
    mean_rate = np.where(counts > 0, sums / np.maximum(counts, 1), overall)
    return frequency_to_sigma(mean_rate)
