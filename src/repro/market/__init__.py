"""repro.market — scenario layers above the core simulator.

* ``trace``       — Google-Cluster-Trace-style machine/task event generation,
                    CSV reading, and trace-driven simulation (paper §VII-C/D).
* ``advisor``     — synthetic AWS Spot-Instance-Advisor dataset (§VII-F).
* ``correlation`` — Theil's U / correlation ratio / Pearson association
                    measures for mixed categorical-numeric data (§VII-F).
"""
from .advisor import generate_advisor_dataset
from .pricing import PriceModel, cost_stats
from .price_process import (
    AuctionPrice,
    SmoothedPrice,
    regime_comparison,
    simulate_price_series,
)
from .correlation import (
    association_matrix,
    correlation_ratio,
    pearson,
    theils_u,
)
from .trace import (
    TraceConfig,
    generate_trace,
    load_trace,
    simulate_trace,
    write_trace_csv,
)

__all__ = [k for k in dir() if not k.startswith("_")]
