"""repro.market — scenario layers above the core simulator.

* ``engine``      — dynamic market engine: multi-pool price clearing +
                    vectorized interruption waves (PRICE_TICK coupling).
* ``pools``       — capacity-pool / regime configuration (calm, volatile,
                    correlated multi-pool).
* ``bids``        — spot bid strategies (on-demand cap, percentile of
                    history, randomized per Bhuyan et al.) + adaptive
                    re-bidding on hibernation (``RebidOnResume``).
* ``migration``   — proactive cross-pool migration planner (PRICE_TICK
                    scoring, MIGRATE_START/COMPLETE execution).
* ``fleet``       — spot-fleet manager: target-capacity allocation across
                    pools with a configurable fallback ladder (same-pool →
                    cheaper-pool → on-demand → queue → scale-down).
* ``faults``      — deterministic seeded market fault injection (capacity
                    crunch, price spike, pool outage, correlated storm)
                    composing with the PRICE_TICK machinery.
* ``risk``        — pool price gradients/volatility + advisor-band-derived
                    pool volatility.
* ``trace``       — Google-Cluster-Trace-style machine/task event generation,
                    CSV reading, and trace-driven simulation (paper §VII-C/D).
* ``advisor``     — synthetic AWS Spot-Instance-Advisor dataset (§VII-F).
* ``correlation`` — Theil's U / correlation ratio / Pearson association
                    measures for mixed categorical-numeric data (§VII-F).
"""
from .advisor import generate_advisor_dataset
from .bids import (
    BID_REGISTRY,
    OnDemandCapBid,
    PercentileBid,
    RandomizedBid,
    RebidOnResume,
    assign_bids,
    make_bid_strategy,
    reference_history,
    register_bid_strategy,
)
from .engine import MarketEngine, price_integral_ref
from .faults import (
    FAULT_KINDS,
    FAULT_REGISTRY,
    FaultEvent,
    FaultInjector,
    make_fault_injector,
    register_fault_scenario,
    storm_victims,
)
from .fleet import (
    FLEET_STRATEGY_REGISTRY,
    FleetConfig,
    FleetManager,
    LADDER_RUNGS,
    fleet_pool_capacity,
    fleet_pool_capacity_ref,
    make_fleet_manager,
    plan_replenish,
    plan_replenish_ref,
    register_fleet_strategy,
    validate_fleet_config,
)
from .migration import (
    MIGRATION_POLICIES,
    MIGRATION_REGISTRY,
    MigrationConfig,
    MigrationPlan,
    MigrationPlanner,
    make_migration_planner,
    plan_reference,
    register_migration_policy,
)
from .pools import MarketConfig, PoolConfig, REGIMES, make_market
from .risk import (
    advisor_pool_volatility,
    bid_crossing_risk,
    price_gradients,
    price_volatility,
    projected_prices,
    simulated_price_fan,
)
from .pricing import PriceModel, cost_stats, realized_cost_stats
from .price_process import (
    AUCTION_FAMILY,
    AuctionPrice,
    MarketState,
    PRICE_PROCESS_REGISTRY,
    SMOOTHED_FAMILY,
    ScalarProcessAdapter,
    SmoothedPrice,
    draw_shock_table,
    regime_comparison,
    register_price_process,
    simulate_price_paths,
    simulate_price_series,
)
from .correlation import (
    association_matrix,
    correlation_ratio,
    pearson,
    theils_u,
)
from .trace import (
    TraceConfig,
    generate_trace,
    load_trace,
    simulate_trace,
    wire_trace,
    write_trace_csv,
)

__all__ = [k for k in dir() if not k.startswith("_")]
