"""Capacity-pool and market-regime configuration for the dynamic market
engine (paper §II-B spot marketspaces; Voorsluys et al. bid-price
provisioning).

A *capacity pool* models one (region, instance-class) spot market: it owns a
price process (``AuctionPrice`` pre-2017 / ``SmoothedPrice`` post-2017) that
clears against the pool's live utilization.  A :class:`MarketConfig` bundles
the pools with the engine's tick interval and an optional cross-pool demand
correlation (a shared utilization shock, so prices of correlated pools spike
together — the "correlated multi-pool" regime of the market-risk analysis).

:func:`make_market` builds the three standard regimes benchmarked in
``launch/market_sim.py --market``:

* ``calm``       — smoothed processes, no shocks: post-2017-style stability.
* ``volatile``   — auction processes with heavy-tailed shocks per pool.
* ``correlated`` — volatile pools driven by a shared demand shock on top of
  their own: diversification across pools stops helping.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

REGIMES = ("calm", "volatile", "correlated")


@dataclass
class PoolConfig:
    """One spot capacity pool (region / instance class)."""

    name: str
    process: str = "smoothed"            # "auction" | "smoothed"
    on_demand_rate: float = 1.0          # price ceiling; prices are fractions
    seed: int = 0
    process_kwargs: Dict[str, float] = field(default_factory=dict)


@dataclass
class MarketConfig:
    pools: List[PoolConfig]
    tick_interval: float = 60.0
    #: weight of the shared demand shock mixed into every pool's utilization
    #: signal (0 = independent pools); drives the correlated regime
    correlation: float = 0.0
    #: std-dev of the shared shock (only used when correlation > 0)
    shock_sigma: float = 0.15
    #: AR(1) persistence of the shared shock: market-wide demand squeezes
    #: span several ticks (0 = the original i.i.d. redraw per tick)
    shock_rho: float = 0.75
    seed: int = 0
    #: fused array-native price tick (default) vs the per-pool scalar
    #: oracle walk — both consume identical shocks and kernels, so full
    #: runs are bit-identical (the oracle exists for cross-validation)
    vectorized: bool = True


def make_market(regime: str, n_pools: int = 2, seed: int = 0,
                tick_interval: float = 60.0,
                on_demand_rate: float = 1.0,
                pool_volatility: Optional[Sequence[float]] = None,
                from_advisor: bool = False) -> MarketConfig:
    """Build a :class:`MarketConfig` for one of the standard regimes.

    Per-pool volatility defaults to the regime's hand-set constant; pass
    ``pool_volatility`` (one sigma per pool) to override it, or set
    ``from_advisor=True`` to derive it from the synthetic Spot-Instance-
    Advisor dataset's interruption-frequency bands
    (:func:`repro.market.risk.advisor_pool_volatility`, same ``seed``) —
    pools inherit the volatility their instance families exhibit in the
    advisor data instead of all sharing one constant."""
    assert regime in REGIMES, f"unknown regime {regime!r} (want {REGIMES})"
    if from_advisor:
        assert pool_volatility is None, (
            "pass either pool_volatility or from_advisor, not both")
        from .risk import advisor_pool_volatility
        pool_volatility = advisor_pool_volatility(n_pools, seed=seed)
    if pool_volatility is not None:
        assert len(pool_volatility) == n_pools, (
            f"pool_volatility needs one entry per pool "
            f"({len(pool_volatility)} != {n_pools})")
    if regime == "calm":
        # smoothed processes: volatility bounds the per-tick step size
        # (the hand-set 0.05 corresponds to the volatile sigma scale / 9)
        def calm_kwargs(i: int) -> Dict[str, float]:
            if pool_volatility is None:
                return {"alpha": 0.2, "max_step": 0.05}
            return {"alpha": 0.2, "max_step": float(pool_volatility[i]) / 9.0}

        pools = [PoolConfig(f"pool{i}", process="smoothed",
                            on_demand_rate=on_demand_rate, seed=seed + i,
                            process_kwargs=calm_kwargs(i))
                 for i in range(n_pools)]
        return MarketConfig(pools, tick_interval=tick_interval, seed=seed)
    # persistent shocks (AR(1) log-shock): pre-2017 price excursions spanned
    # many samples — waves build and decay over several ticks
    pools = [PoolConfig(f"pool{i}", process="auction",
                        on_demand_rate=on_demand_rate, seed=seed + i,
                        process_kwargs={"shock_sigma": 0.45
                                        if pool_volatility is None
                                        else float(pool_volatility[i]),
                                        "shock_rho": 0.75})
             for i in range(n_pools)]
    corr = 0.8 if regime == "correlated" else 0.0
    return MarketConfig(pools, tick_interval=tick_interval,
                        correlation=corr, shock_sigma=0.2, seed=seed)
