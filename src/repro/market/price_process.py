"""Spot price processes (paper §II-B).

The paper recounts the 2017 AWS pricing change: originally spot prices came
from a market auction (highly volatile, rewarding bidding strategies); since
2017 they follow "smoothed demand–supply trends" (volatility down, long-term
averages down, short-lived workloads relatively more expensive).  We model
both regimes so simulations can price interruptions under either:

* ``AuctionPrice``  — pre-2017: clearing price = utilization-driven inverse
  supply curve + heavy-tailed demand shocks (lognormal), floor at a reserve.
* ``SmoothedPrice`` — post-2017: exponentially smoothed utilization signal
  mapped through the same curve; bounded step size per interval.

Both are seeded and driven by the *simulated fleet utilization*, so policy
choices feed back into prices (e.g. tighter packing → higher clearing
prices) — the "dynamic marketspace" the title refers to.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

import numpy as np

from ..core.registry import Registry

#: string-keyed registry of price processes; ``PoolConfig.process`` resolves
#: against it, so custom processes plug into the market engine by name:
#: ``@register_price_process("my-process")``.  Factories are called with
#: ``on_demand_rate``, ``seed``, and the pool's ``process_kwargs``.
PRICE_PROCESS_REGISTRY = Registry("price process")
register_price_process = PRICE_PROCESS_REGISTRY.register


def _supply_curve(utilization: float, on_demand_rate: float) -> float:
    """Spot clearing price as a convex function of fleet utilization:
    ~10% of on-demand when idle, approaching on-demand as capacity runs out.
    """
    u = min(max(utilization, 0.0), 1.0)
    return on_demand_rate * (0.1 + 0.9 * u ** 3)


def supply_curve_slope(utilization, on_demand_rate):
    """d(price)/d(utilization) of :func:`_supply_curve` — the migration
    planner's price-impact model reads the same curve the market clears on
    (vectorized: accepts arrays)."""
    u = np.clip(utilization, 0.0, 1.0)
    return on_demand_rate * 2.7 * u ** 2


@register_price_process("auction")
@dataclass
class AuctionPrice:
    """Pre-2017 auction regime: volatile, shock-driven.

    ``shock_rho`` adds AR(1) persistence to the log-shock (stationary
    variance held at ``shock_sigma``²): real pre-2017 price excursions
    lasted hours, not one sample — persistence is what makes them *waves* a
    gradient-aware policy can see coming.  ``shock_rho=0`` (default)
    reproduces the original i.i.d. lognormal shocks bit-exactly."""
    on_demand_rate: float = 1.0
    shock_sigma: float = 0.35
    shock_rho: float = 0.0
    seed: int = 0
    _rng: np.random.Generator = field(init=False, repr=False)
    _log_shock: float = field(init=False, repr=False, default=0.0)

    def __post_init__(self):
        assert 0.0 <= self.shock_rho < 1.0
        self._rng = np.random.default_rng(self.seed)

    def price(self, utilization: float) -> float:
        base = _supply_curve(utilization, self.on_demand_rate)
        if self.shock_rho == 0.0:
            shock = float(self._rng.lognormal(0.0, self.shock_sigma))
        else:
            innov_sigma = self.shock_sigma * float(
                np.sqrt(1.0 - self.shock_rho ** 2))
            self._log_shock = (self.shock_rho * self._log_shock
                               + float(self._rng.normal(0.0, innov_sigma)))
            shock = float(np.exp(self._log_shock))
        return float(min(base * shock, self.on_demand_rate))


@register_price_process("smoothed")
@dataclass
class SmoothedPrice:
    """Post-2017 regime: EWMA-smoothed utilization, bounded price steps."""
    on_demand_rate: float = 1.0
    alpha: float = 0.05           # smoothing factor
    max_step: float = 0.02        # max relative change per interval
    seed: int = 0
    _u_smooth: float = 0.0
    _last: float = 0.1

    def price(self, utilization: float) -> float:
        self._u_smooth = (self.alpha * utilization
                          + (1 - self.alpha) * self._u_smooth)
        target = _supply_curve(self._u_smooth, self.on_demand_rate)
        lo = self._last * (1 - self.max_step)
        hi = self._last * (1 + self.max_step)
        self._last = float(min(max(target, lo), hi))
        return self._last


def simulate_price_series(process, utilizations) -> np.ndarray:
    return np.asarray([process.price(u) for u in utilizations])


def regime_comparison(n: int = 2000, seed: int = 0) -> dict:
    """Reproduce the paper's qualitative §II-B claims on a shared utilization
    path: post-2017 volatility is far lower and the long-term average drops,
    while short spot sessions see relatively higher mean prices under the
    smoothed regime than lucky auction dips would give them."""
    rng = np.random.default_rng(seed)
    # mean-reverting utilization path with diurnal swing
    u, us = 0.6, []
    for t in range(n):
        diurnal = 0.15 * np.sin(2 * np.pi * t / 288.0)
        u += 0.05 * (0.6 + diurnal - u) + 0.03 * rng.normal()
        us.append(min(max(u, 0.05), 0.99))
    auction = simulate_price_series(AuctionPrice(seed=seed), us)
    smoothed = simulate_price_series(SmoothedPrice(seed=seed), us)
    warm = n // 4                   # drop the EWMA warm-up transient
    auction, smoothed = auction[warm:], smoothed[warm:]
    short = slice(0, 50)  # a short-lived workload window
    return {
        "auction_mean": float(auction.mean()),
        "smoothed_mean": float(smoothed.mean()),
        "auction_cv": float(auction.std() / auction.mean()),
        "smoothed_cv": float(smoothed.std() / smoothed.mean()),
        "auction_short_mean": float(auction[short].mean()),
        "smoothed_short_mean": float(smoothed[short].mean()),
    }
