"""Spot price processes (paper §II-B) — scalar oracles + array-native families.

The paper recounts the 2017 AWS pricing change: originally spot prices came
from a market auction (highly volatile, rewarding bidding strategies); since
2017 they follow "smoothed demand–supply trends" (volatility down, long-term
averages down, short-lived workloads relatively more expensive).  We model
both regimes so simulations can price interruptions under either:

* ``AuctionPrice``  — pre-2017: clearing price = utilization-driven inverse
  supply curve + heavy-tailed demand shocks (lognormal), floor at a reserve.
* ``SmoothedPrice`` — post-2017: exponentially smoothed utilization signal
  mapped through the same curve; bounded step size per interval.

Both are seeded and driven by the *simulated fleet utilization*, so policy
choices feed back into prices (e.g. tighter packing → higher clearing
prices) — the "dynamic marketspace" the title refers to.

Array-native protocol (the PRICE_TICK hot path)
-----------------------------------------------

Each process kind is also a **family**: a stateless step function over a
packed :data:`MarketState` pytree (one ``(n_pools,)`` array per field).
The market engine pre-draws a per-tick ``(n_pools,)`` standard-normal shock
vector from per-pool streams, so the legacy scalar objects and the
vectorized path consume *identical* randomness — one fused numpy call per
tick replaces the per-pool Python ``price()`` walk, and the scalar oracle
stays bit-identical for cross-validation:

* ``family.init(pool_kwargs)``          → packed state for fresh pools
* ``family.pack(processes)``            → packed state from live scalar objects
* ``family.step(state, util, shock)``   → ``(state, prices)``  (pure)
* ``family.make_scalar(**kwargs)``      → one legacy scalar process

``PRICE_PROCESS_REGISTRY`` now registers *families*;
``@register_price_process`` keeps name compatibility for the legacy object
protocol (a class exposing ``price(utilization)``) by wrapping it in a
:class:`ScalarProcessAdapter`, so custom processes keep working inside the
engine — they run through a per-pool scalar loop instead of the fused path.

Scalar processes that implement the shared-shock protocol advertise
``shock_protocol = True`` and accept ``price(utilization, shock=z)``; with
``shock=None`` they reproduce the historical internally-drawing behavior
bit-exactly (regression-pinned by golden series in the test suite).

:func:`simulate_price_paths` runs a family ``T`` steps over pre-drawn shock
tables — with ``backend="jax"`` as one ``jax.lax.scan`` — for offline
multi-path price simulation (``risk.simulated_price_fan``,
:func:`regime_comparison`).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.registry import Registry

#: packed structure-of-arrays price state: every leaf is an ``(n_pools,)``
#: float64 array (a pytree — ``jax.lax.scan`` carries it unchanged)
MarketState = Dict[str, np.ndarray]

#: string-keyed registry of price-process *families*; ``PoolConfig.process``
#: resolves against it, so custom processes plug into the market engine by
#: name: ``@register_price_process("my-process")``.  Scalar factories are
#: called with ``on_demand_rate``, ``seed``, and the pool's
#: ``process_kwargs``.
PRICE_PROCESS_REGISTRY = Registry("price process")


def _supply_curve(utilization: float, on_demand_rate: float) -> float:
    """Spot clearing price as a convex function of fleet utilization:
    ~10% of on-demand when idle, approaching on-demand as capacity runs out.
    (Scalar legacy form; the packed kernels use :func:`supply_curve_arr`.)
    """
    u = min(max(utilization, 0.0), 1.0)
    return on_demand_rate * (0.1 + 0.9 * u ** 3)


def supply_curve_arr(utilization, on_demand_rate, xp=np):
    """Vectorized :func:`_supply_curve` — the packed kernels' base price.
    ``xp`` selects the array namespace (numpy, or ``jax.numpy`` under
    ``lax.scan``)."""
    u = xp.clip(utilization, 0.0, 1.0)
    return on_demand_rate * (0.1 + 0.9 * u ** 3)


def supply_curve_slope(utilization, on_demand_rate):
    """d(price)/d(utilization) of :func:`_supply_curve` — the migration
    planner's price-impact model reads the same curve the market clears on
    (vectorized: accepts arrays)."""
    u = np.clip(utilization, 0.0, 1.0)
    return on_demand_rate * 2.7 * u ** 2


# ---------------------------------------------------------------------------
# scalar processes (the per-pool oracles)
# ---------------------------------------------------------------------------
@dataclass
class AuctionPrice:
    """Pre-2017 auction regime: volatile, shock-driven.

    ``shock_rho`` adds AR(1) persistence to the log-shock (stationary
    variance held at ``shock_sigma``²): real pre-2017 price excursions
    lasted hours, not one sample — persistence is what makes them *waves* a
    gradient-aware policy can see coming.  ``shock_rho=0`` (default)
    reproduces the original i.i.d. lognormal shocks bit-exactly.

    ``price(u)`` draws from the process' own RNG (legacy protocol);
    ``price(u, shock=z)`` consumes an externally drawn standard-normal shock
    through the packed :data:`AUCTION_FAMILY` kernel — bit-identical to the
    engine's fused vectorized tick."""
    on_demand_rate: float = 1.0
    shock_sigma: float = 0.35
    shock_rho: float = 0.0
    seed: int = 0
    _rng: np.random.Generator = field(init=False, repr=False)
    _log_shock: float = field(init=False, repr=False, default=0.0)

    #: accepts the engine's shared per-tick shock vector
    shock_protocol = True

    def __post_init__(self):
        assert 0.0 <= self.shock_rho < 1.0
        self._rng = np.random.default_rng(self.seed)
        self._packed: Optional[MarketState] = None

    def price(self, utilization: float, shock: Optional[float] = None) -> float:
        if shock is None:   # legacy path: internal draw, historical bits
            base = _supply_curve(utilization, self.on_demand_rate)
            if self.shock_rho == 0.0:
                s = float(self._rng.lognormal(0.0, self.shock_sigma))
            else:
                innov_sigma = self.shock_sigma * float(
                    np.sqrt(1.0 - self.shock_rho ** 2))
                self._log_shock = (self.shock_rho * self._log_shock
                                   + float(self._rng.normal(0.0, innov_sigma)))
                s = float(np.exp(self._log_shock))
            return float(min(base * s, self.on_demand_rate))
        # shared-shock protocol: the 1-element packed kernel, so the scalar
        # oracle and the fused vectorized tick are bit-identical.  Dynamic
        # state is re-synced from the scalar fields each call, so legacy
        # and shock-protocol calls may interleave without divergence.
        if self._packed is None:
            self._packed = AUCTION_FAMILY.pack([self])
        else:
            self._packed["log_shock"][0] = self._log_shock
        self._packed, p = AUCTION_FAMILY.step(
            self._packed, np.asarray([utilization], dtype=np.float64),
            np.asarray([shock], dtype=np.float64))
        self._log_shock = float(self._packed["log_shock"][0])
        return float(p[0])


@dataclass
class SmoothedPrice:
    """Post-2017 regime: EWMA-smoothed utilization, bounded price steps.

    Fully deterministic — it draws no randomness, so (unlike the pre-PR5
    dataclass) there is no ``seed`` field to silently swallow; passing one
    raises at construction.  ``price(u, shock=z)`` accepts and ignores the
    engine's shared shock (protocol uniformity)."""
    on_demand_rate: float = 1.0
    alpha: float = 0.05           # smoothing factor
    max_step: float = 0.02        # max relative change per interval
    _u_smooth: float = 0.0
    _last: float = 0.1

    shock_protocol = True

    def __post_init__(self):
        self._packed: Optional[MarketState] = None

    def price(self, utilization: float, shock: Optional[float] = None) -> float:
        if shock is None:   # legacy path, historical bits
            self._u_smooth = (self.alpha * utilization
                              + (1 - self.alpha) * self._u_smooth)
            target = _supply_curve(self._u_smooth, self.on_demand_rate)
            lo = self._last * (1 - self.max_step)
            hi = self._last * (1 + self.max_step)
            self._last = float(min(max(target, lo), hi))
            return self._last
        if self._packed is None:
            self._packed = SMOOTHED_FAMILY.pack([self])
        else:
            # re-sync dynamic state so legacy and shock-protocol calls
            # may interleave without divergence
            self._packed["u_smooth"][0] = self._u_smooth
            self._packed["last"][0] = self._last
        self._packed, p = SMOOTHED_FAMILY.step(
            self._packed, np.asarray([utilization], dtype=np.float64),
            np.asarray([shock], dtype=np.float64))
        self._u_smooth = float(self._packed["u_smooth"][0])
        self._last = float(self._packed["last"][0])
        return self._last


# ---------------------------------------------------------------------------
# families (stateless step functions over packed MarketState)
# ---------------------------------------------------------------------------
class AuctionFamily:
    """Packed ``AuctionPrice``: one fused step for a whole pool vector.

    State leaves: ``od`` (rate ceiling), ``rho`` (AR(1) persistence),
    ``innov`` (innovation sigma, = sigma·√(1−rho²); equals sigma when
    rho = 0, so the i.i.d. and AR(1) cases share one recurrence),
    ``log_shock`` (the evolving AR(1) log-shock)."""

    name = "auction"
    vectorized = True
    scalar_cls = AuctionPrice

    def make_scalar(self, **kwargs) -> AuctionPrice:
        return AuctionPrice(**kwargs)

    def init(self, pool_kwargs: Sequence[Dict]) -> MarketState:
        return self.pack([AuctionPrice(**kw) for kw in pool_kwargs])

    def pack(self, procs: Sequence[AuctionPrice]) -> MarketState:
        return {
            "od": np.array([p.on_demand_rate for p in procs], dtype=np.float64),
            "rho": np.array([p.shock_rho for p in procs], dtype=np.float64),
            "innov": np.array(
                [p.shock_sigma * float(np.sqrt(1.0 - p.shock_rho ** 2))
                 for p in procs], dtype=np.float64),
            "log_shock": np.array([p._log_shock for p in procs],
                                  dtype=np.float64),
        }

    def step(self, state: MarketState, util, shock,
             xp=np) -> Tuple[MarketState, np.ndarray]:
        base = supply_curve_arr(util, state["od"], xp)
        # rho=0 ⇒ log_shock = sigma·z ⇒ the historical i.i.d. lognormal
        log_shock = state["rho"] * state["log_shock"] + state["innov"] * shock
        prices = xp.minimum(base * xp.exp(log_shock), state["od"])
        return {**state, "log_shock": log_shock}, prices


class SmoothedFamily:
    """Packed ``SmoothedPrice``: EWMA + step-bounded supply curve, fused.

    Deterministic — ``shock`` is accepted and ignored (protocol uniformity);
    ``make_scalar`` likewise discards the ``seed`` the engine supplies to
    every pool."""

    name = "smoothed"
    vectorized = True
    scalar_cls = SmoothedPrice

    def make_scalar(self, seed: int = 0, **kwargs) -> SmoothedPrice:
        del seed  # deterministic process; engine supplies seeds uniformly
        return SmoothedPrice(**kwargs)

    def init(self, pool_kwargs: Sequence[Dict]) -> MarketState:
        return self.pack([self.make_scalar(**kw) for kw in pool_kwargs])

    def pack(self, procs: Sequence[SmoothedPrice]) -> MarketState:
        return {
            "od": np.array([p.on_demand_rate for p in procs], dtype=np.float64),
            "alpha": np.array([p.alpha for p in procs], dtype=np.float64),
            "max_step": np.array([p.max_step for p in procs],
                                 dtype=np.float64),
            "u_smooth": np.array([p._u_smooth for p in procs],
                                 dtype=np.float64),
            "last": np.array([p._last for p in procs], dtype=np.float64),
        }

    def step(self, state: MarketState, util, shock,
             xp=np) -> Tuple[MarketState, np.ndarray]:
        u_s = state["alpha"] * util + (1 - state["alpha"]) * state["u_smooth"]
        target = supply_curve_arr(u_s, state["od"], xp)
        lo = state["last"] * (1 - state["max_step"])
        hi = state["last"] * (1 + state["max_step"])
        last = xp.minimum(xp.maximum(target, lo), hi)
        return {**state, "u_smooth": u_s, "last": last}, last


class ScalarProcessAdapter:
    """Registry adapter for the legacy object protocol: a class exposing
    ``price(utilization)``.  ``step`` walks the wrapped per-pool objects in
    Python — custom processes keep working in the engine, just not fused."""

    vectorized = False

    def __init__(self, name: str, factory):
        self.name = name
        self.factory = factory

    def make_scalar(self, **kwargs):
        return self.factory(**kwargs)

    def init(self, pool_kwargs: Sequence[Dict]) -> MarketState:
        return self.pack([self.factory(**kw) for kw in pool_kwargs])

    def pack(self, procs) -> MarketState:
        return {"procs": list(procs)}

    def step(self, state, util, shock, xp=np):
        del shock
        prices = np.array([p.price(float(u))
                           for p, u in zip(state["procs"], util)],
                          dtype=np.float64)
        return state, prices


def _is_family(obj) -> bool:
    return all(hasattr(obj, a) for a in ("init", "pack", "step",
                                         "make_scalar"))


def register_price_process(name: str, obj=None, overwrite: bool = False):
    """Register a price process under ``name``.

    Accepts either a *family* (``init``/``pack``/``step``/``make_scalar``)
    or — for backward compatibility — a legacy scalar class exposing
    ``price(utilization)``, which is wrapped in a
    :class:`ScalarProcessAdapter`.  Usable as a decorator."""
    def _wrap(target):
        entry = target if _is_family(target) else \
            ScalarProcessAdapter(name, target)
        PRICE_PROCESS_REGISTRY.register(name, entry, overwrite=overwrite)
        return target
    return _wrap if obj is None else _wrap(obj)


AUCTION_FAMILY = AuctionFamily()
SMOOTHED_FAMILY = SmoothedFamily()
register_price_process("auction", AUCTION_FAMILY)
register_price_process("smoothed", SMOOTHED_FAMILY)
#: scalar class -> family, for the engine's packed grouping
AuctionPrice.family = AUCTION_FAMILY
SmoothedPrice.family = SMOOTHED_FAMILY


# ---------------------------------------------------------------------------
# shock tables + offline path simulation (numpy loop / jax.lax.scan)
# ---------------------------------------------------------------------------
def draw_shock_table(seeds: Sequence[int], n_ticks: int) -> np.ndarray:
    """(n_ticks, n_pools) standard-normal shock table, column ``i`` drawn
    from ``default_rng(seeds[i])`` — the exact per-pool streams the engine
    consumes tick by tick, so offline replays see identical randomness."""
    cols = [np.random.default_rng(s).standard_normal(n_ticks) for s in seeds]
    return np.stack(cols, axis=1) if cols else np.zeros((n_ticks, 0),
                                                        dtype=np.float64)


def simulate_price_paths(family, state: MarketState, utils, shocks,
                         backend: str = "numpy"):
    """Run ``family.step`` over ``n_ticks`` pre-drawn inputs.

    ``utils`` / ``shocks``: ``(T, ...)`` arrays, broadcastable against the
    state leaves — e.g. ``(T, n_pools)`` for one path, or
    ``(T, n_paths, n_pools)`` for a Monte-Carlo fan (the kernels broadcast).
    Returns ``(prices, final_state)`` with ``prices`` shaped like the
    stepped inputs stacked over ``T``.

    ``backend="jax"`` fuses the whole simulation into one
    ``jax.lax.scan`` (float64); ``"numpy"`` is the reference step loop.
    Adapter-wrapped legacy processes only support the numpy backend."""
    utils = np.asarray(utils, dtype=np.float64)
    shocks = np.asarray(shocks, dtype=np.float64)
    assert utils.shape[0] == shocks.shape[0], "utils/shocks tick mismatch"
    if backend == "numpy":
        out = []
        for t in range(shocks.shape[0]):
            state, p = family.step(state, utils[t], shocks[t])
            out.append(np.asarray(p, dtype=np.float64))
        return (np.stack(out) if out
                else np.zeros_like(shocks)), state
    if backend != "jax":
        raise ValueError(f"unknown backend {backend!r} (want numpy|jax)")
    if not getattr(family, "vectorized", False):
        raise ValueError(
            "jax backend needs an array-native family (adapter-wrapped "
            "legacy processes only support backend='numpy')")
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    with enable_x64():
        def _step(carry, xs):
            u, z = xs
            carry, p = family.step(carry, u, z, xp=jnp)
            return carry, p

        # scan carries must keep a fixed shape: pre-broadcast every state
        # leaf to the per-tick shock shape (no-op for single-path runs,
        # (n_paths, n_pools) for Monte-Carlo fans)
        state64 = {k: jnp.broadcast_to(jnp.asarray(v, dtype=jnp.float64),
                                       shocks.shape[1:])
                   for k, v in state.items()}
        final, prices = jax.lax.scan(
            _step, state64, (jnp.asarray(utils, dtype=jnp.float64),
                             jnp.asarray(shocks, dtype=jnp.float64)))
        return (np.asarray(prices, dtype=np.float64),
                {k: np.asarray(v, dtype=np.float64) for k, v in final.items()})


def simulate_price_series(process, utilizations) -> np.ndarray:
    return np.asarray([process.price(u) for u in utilizations],
                      dtype=np.float64)


def _mean_reverting_utilization(n: int, seed: int) -> List[float]:
    rng = np.random.default_rng(seed)
    u, us = 0.6, []
    for t in range(n):
        diurnal = 0.15 * np.sin(2 * np.pi * t / 288.0)
        u += 0.05 * (0.6 + diurnal - u) + 0.03 * rng.normal()
        us.append(min(max(u, 0.05), 0.99))
    return us


def regime_comparison(n: int = 2000, seed: int = 0,
                      use_scan: bool = False) -> dict:
    """Reproduce the paper's qualitative §II-B claims on a shared utilization
    path: post-2017 volatility is far lower and the long-term average drops,
    while short spot sessions see relatively higher mean prices under the
    smoothed regime than lucky auction dips would give them.

    ``use_scan=True`` computes both series through the array-native
    families and one ``jax.lax.scan`` each (identical shock stream; equal
    to the scalar walk up to last-ULP exp/pow differences)."""
    us = _mean_reverting_utilization(n, seed)
    if use_scan:
        utils = np.asarray(us, dtype=np.float64)[:, None]  # (T, 1)
        shocks = draw_shock_table([seed], n)             # auction's stream
        auction, _ = simulate_price_paths(
            AUCTION_FAMILY, AUCTION_FAMILY.init([{"seed": seed}]),
            utils, shocks, backend="jax")
        smoothed, _ = simulate_price_paths(
            SMOOTHED_FAMILY, SMOOTHED_FAMILY.init([{}]),
            utils, np.zeros_like(shocks), backend="jax")
        auction, smoothed = auction[:, 0], smoothed[:, 0]
    else:
        auction = simulate_price_series(AuctionPrice(seed=seed), us)
        smoothed = simulate_price_series(SmoothedPrice(), us)
    warm = n // 4                   # drop the EWMA warm-up transient
    auction, smoothed = auction[warm:], smoothed[warm:]
    short = slice(0, 50)  # a short-lived workload window
    return {
        "auction_mean": float(auction.mean()),
        "smoothed_mean": float(smoothed.mean()),
        "auction_cv": float(auction.std() / auction.mean()),
        "smoothed_cv": float(smoothed.std() / smoothed.mean()),
        "auction_short_mean": float(auction[short].mean()),
        "smoothed_short_mean": float(smoothed[short].mean()),
    }
