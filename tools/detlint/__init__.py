"""detlint: determinism & purity static analysis for this repository.

See tools/detlint/core.py for the engine and README "Static analysis"
for the rule table, suppression syntax, and baseline workflow.
"""

from .cli import default_passes, default_rules, main
from .core import Finding, Pass, Report, Rule, run_lint

__all__ = [
    "Finding",
    "Pass",
    "Report",
    "Rule",
    "default_passes",
    "default_rules",
    "main",
    "run_lint",
]
