"""Baseline persistence: grandfathered findings by fingerprint.

The baseline is a committed JSON file mapping finding fingerprints
(rule + path + normalized line text) to allowed multiplicities.  The
lint gate only fails on findings *not* covered by the baseline, so
pre-existing debt can be burned down incrementally without blocking CI.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Iterable, List

from .core import Finding


def load_baseline(path: Path) -> List[Dict[str, object]]:
    if not path.is_file():
        return []
    data = json.loads(path.read_text(encoding="utf-8"))
    entries = data.get("findings", []) if isinstance(data, dict) else data
    return [e for e in entries if isinstance(e, dict)]


def baseline_counts(entries: Iterable[Dict[str, object]]) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for entry in entries:
        fp = entry.get("fingerprint")
        if isinstance(fp, str):
            counts[fp] = counts.get(fp, 0) + 1
    return counts


def write_baseline(path: Path, findings: Iterable[Finding]) -> None:
    entries = [
        {
            "fingerprint": f.fingerprint(),
            "rule": f.rule,
            "path": f.path,
            "line": f.line,
            "message": f.message,
        }
        for f in findings
        if f.status != "suppressed"
    ]
    payload = {"schema": "detlint.baseline", "version": 1, "findings": entries}
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n",
                    encoding="utf-8")
