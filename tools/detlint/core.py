"""detlint core: findings, rule/pass protocol, file contexts, engine.

detlint is an AST-based static-analysis suite purpose-built for this
repository's determinism and reproducibility contracts.  It has two rule
tiers:

* **per-file rules** — walk one module's AST at a time (wall-clock reads,
  global RNG, unordered float accumulation, jit purity, dtype discipline);
* **cross-module passes** — see the whole scanned tree at once and check
  consistency properties a single file cannot express (event coverage,
  registry coverage, spec round-trip fields).

Findings flow through two filters before they fail a run:

1. inline suppressions — ``# detlint: disable=<rule>[,<rule>...]`` on the
   flagged line (or ``# detlint: disable-file=<rule>`` anywhere in the
   file) silence a finding at the source, with the rest of the comment
   acting as the justification;
2. a committed JSON baseline (``tools/detlint/baseline.json``) grandfathers
   known findings by (rule, path, fingerprint) so the gate only trips on
   *new* violations.
"""

from __future__ import annotations

import ast
import hashlib
import io
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = [
    "Finding",
    "FileContext",
    "Project",
    "Rule",
    "Pass",
    "Report",
    "collect_files",
    "load_file_context",
    "run_lint",
]


# --------------------------------------------------------------------------
# Findings
# --------------------------------------------------------------------------

STATUS_NEW = "new"
STATUS_SUPPRESSED = "suppressed"
STATUS_BASELINED = "baselined"


@dataclass
class Finding:
    """One violation at a (rule, file, line) location."""

    rule: str
    path: str  # repo-root-relative, posix separators
    line: int
    col: int
    message: str
    status: str = STATUS_NEW
    justification: str = ""
    line_text: str = ""

    def fingerprint(self) -> str:
        """Stable identity for baseline matching.

        Deliberately excludes the line *number* so unrelated edits above a
        grandfathered finding do not un-baseline it; uses the stripped
        source line instead.
        """
        payload = "\0".join([self.rule, self.path, self.line_text.strip()])
        return hashlib.sha1(payload.encode("utf-8")).hexdigest()[:16]

    def to_dict(self) -> Dict[str, object]:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "status": self.status,
            "justification": self.justification,
            "fingerprint": self.fingerprint(),
        }

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"


# --------------------------------------------------------------------------
# Suppressions
# --------------------------------------------------------------------------

_SUPPRESS_RE = re.compile(r"detlint:\s*disable=([A-Za-z0-9_,\- ]+)")
_SUPPRESS_FILE_RE = re.compile(r"detlint:\s*disable-file=([A-Za-z0-9_,\- ]+)")


def _parse_rule_list(raw: str) -> Set[str]:
    return {part.strip() for part in raw.split(",") if part.strip()}


def parse_suppressions(source: str) -> Tuple[Dict[int, Set[str]], Set[str], Dict[int, str]]:
    """Extract inline suppressions from comments.

    Returns ``(line -> rules, file_rules, line -> justification)``.  Rule
    name ``all`` disables every rule.  Only real comment tokens count —
    string literals that merely contain the marker are ignored.
    """
    by_line: Dict[int, Set[str]] = {}
    file_wide: Set[str] = set()
    notes: Dict[int, str] = {}
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            text = tok.string
            m = _SUPPRESS_FILE_RE.search(text)
            if m:
                file_wide |= _parse_rule_list(m.group(1))
                continue
            m = _SUPPRESS_RE.search(text)
            if m:
                lineno = tok.start[0]
                by_line.setdefault(lineno, set()).update(_parse_rule_list(m.group(1)))
                tail = text[m.end():].strip(" -#\t")
                if tail:
                    notes[lineno] = tail
    except tokenize.TokenError:
        pass  # unterminated source; the parse-error finding covers it
    return by_line, file_wide, notes


# --------------------------------------------------------------------------
# File contexts
# --------------------------------------------------------------------------


@dataclass
class FileContext:
    """Parsed view of one source file handed to rules and passes."""

    path: Path
    rel: str
    source: str
    lines: List[str]
    tree: Optional[ast.AST]
    parse_error: Optional[str]
    suppress_line: Dict[int, Set[str]] = field(default_factory=dict)
    suppress_file: Set[str] = field(default_factory=set)
    suppress_notes: Dict[int, str] = field(default_factory=dict)

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def is_suppressed(self, rule: str, lineno: int) -> Tuple[bool, str]:
        if rule in self.suppress_file or "all" in self.suppress_file:
            return True, "file-wide suppression"
        rules = self.suppress_line.get(lineno, set())
        if rule in rules or "all" in rules:
            return True, self.suppress_notes.get(lineno, "")
        return False, ""


@dataclass
class Project:
    """Whole-scan view handed to cross-module passes."""

    root: Path
    files: List[FileContext]
    tests_dir: Path

    def find(self, suffix: str) -> Optional[FileContext]:
        """Locate a scanned file whose repo-relative path ends with *suffix*."""
        for ctx in self.files:
            if ctx.rel.endswith(suffix):
                return ctx
        return None

    def test_sources(self) -> List[Tuple[Path, str]]:
        """Read every test file (path, source) under the tests directory."""
        out: List[Tuple[Path, str]] = []
        if not self.tests_dir.is_dir():
            return out
        for path in sorted(self.tests_dir.rglob("*.py")):
            if "__pycache__" in path.parts:
                continue
            try:
                out.append((path, path.read_text(encoding="utf-8")))
            except OSError:
                continue
        return out


class Rule:
    """Base class for per-file rules."""

    id: str = ""
    description: str = ""

    def check(self, ctx: FileContext) -> Iterable[Finding]:  # pragma: no cover
        raise NotImplementedError


class Pass:
    """Base class for whole-repo cross-module passes."""

    id: str = ""
    description: str = ""

    def check(self, project: Project) -> Iterable[Finding]:  # pragma: no cover
        raise NotImplementedError


# --------------------------------------------------------------------------
# Engine
# --------------------------------------------------------------------------


def collect_files(paths: Sequence[Path], root: Path) -> List[Path]:
    """Expand target paths into a sorted list of .py files."""
    seen: Set[Path] = set()
    out: List[Path] = []
    for target in paths:
        target = (root / target) if not target.is_absolute() else target
        if target.is_dir():
            candidates = sorted(target.rglob("*.py"))
        elif target.is_file():
            candidates = [target]
        else:
            continue
        for cand in candidates:
            if "__pycache__" in cand.parts or cand.name.startswith("."):
                continue
            resolved = cand.resolve()
            if resolved not in seen:
                seen.add(resolved)
                out.append(cand)
    return out


def load_file_context(path: Path, root: Path) -> FileContext:
    source = path.read_text(encoding="utf-8")
    try:
        rel = path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        rel = path.as_posix()
    tree: Optional[ast.AST] = None
    parse_error: Optional[str] = None
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as exc:
        parse_error = f"syntax error: {exc.msg} (line {exc.lineno})"
    by_line, file_wide, notes = parse_suppressions(source)
    return FileContext(
        path=path,
        rel=rel,
        source=source,
        lines=source.splitlines(),
        tree=tree,
        parse_error=parse_error,
        suppress_line=by_line,
        suppress_file=file_wide,
        suppress_notes=notes,
    )


@dataclass
class Report:
    findings: List[Finding]
    files_scanned: int
    rules_run: List[str]

    @property
    def new_findings(self) -> List[Finding]:
        return [f for f in self.findings if f.status == STATUS_NEW]

    @property
    def exit_code(self) -> int:
        return 1 if self.new_findings else 0

    def to_dict(self) -> Dict[str, object]:
        by_status: Dict[str, int] = {}
        for f in self.findings:
            by_status[f.status] = by_status.get(f.status, 0) + 1
        return {
            "tool": "detlint",
            "files_scanned": self.files_scanned,
            "rules": self.rules_run,
            "counts": by_status,
            "new": len(self.new_findings),
            "findings": [f.to_dict() for f in self.findings],
        }


def _apply_filters(
    findings: List[Finding],
    contexts: Dict[str, FileContext],
    baseline_counts: Dict[str, int],
) -> None:
    """Mark findings suppressed/baselined in place (order: suppressions win)."""
    remaining = dict(baseline_counts)
    for f in findings:
        ctx = contexts.get(f.path)
        if ctx is not None:
            suppressed, note = ctx.is_suppressed(f.rule, f.line)
            if suppressed:
                f.status = STATUS_SUPPRESSED
                f.justification = note
                continue
        fp = f.fingerprint()
        if remaining.get(fp, 0) > 0:
            remaining[fp] -= 1
            f.status = STATUS_BASELINED


def run_lint(
    paths: Sequence[Path],
    root: Path,
    rules: Sequence[Rule],
    passes: Sequence[Pass],
    baseline_counts: Optional[Dict[str, int]] = None,
    tests_dir: Optional[Path] = None,
    only: Optional[Set[str]] = None,
) -> Report:
    """Run the configured rules and passes over *paths*.

    ``only`` restricts execution to the named rule/pass ids.  The baseline
    maps fingerprint -> allowed count (multiplicity-aware).
    """
    root = root.resolve()
    files = collect_files(paths, root)
    contexts = [load_file_context(p, root) for p in files]
    by_rel = {ctx.rel: ctx for ctx in contexts}
    project = Project(
        root=root,
        files=contexts,
        tests_dir=(tests_dir if tests_dir is not None else root / "tests"),
    )

    active_rules = [r for r in rules if only is None or r.id in only]
    active_passes = [p for p in passes if only is None or p.id in only]

    findings: List[Finding] = []
    for ctx in contexts:
        if ctx.parse_error is not None:
            findings.append(
                Finding(
                    rule="parse-error",
                    path=ctx.rel,
                    line=1,
                    col=0,
                    message=ctx.parse_error,
                    line_text=ctx.line_text(1),
                )
            )
            continue
        for rule in active_rules:
            for f in rule.check(ctx):
                f.line_text = f.line_text or ctx.line_text(f.line)
                findings.append(f)
    for pazz in active_passes:
        for f in pazz.check(project):
            ctx = by_rel.get(f.path)
            if ctx is not None:
                f.line_text = f.line_text or ctx.line_text(f.line)
            findings.append(f)

    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    _apply_filters(findings, by_rel, dict(baseline_counts or {}))
    rule_ids = [r.id for r in active_rules] + [p.id for p in active_passes]
    return Report(findings=findings, files_scanned=len(contexts), rules_run=rule_ids)
