"""Shared AST helpers for detlint rules and passes."""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set

__all__ = [
    "dotted_name",
    "ImportMap",
    "const_strings",
    "call_name_node",
    "iter_string_constants",
    "assigned_names",
    "name_root",
    "module_string_sequences",
]


def dotted_name(node: ast.AST) -> Optional[str]:
    """Render a Name/Attribute chain as ``a.b.c``; None for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def name_root(node: ast.AST) -> Optional[str]:
    """Leftmost Name id of a Name/Attribute/Subscript chain, else None."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


class ImportMap:
    """Resolve local aliases to fully-qualified dotted names.

    Handles ``import numpy as np`` (np -> numpy), ``from time import
    perf_counter as pc`` (pc -> time.perf_counter), and plain imports.
    ``resolve(node)`` expands the leading alias of a Name/Attribute chain,
    so ``np.random.seed`` resolves to ``numpy.random.seed``.
    """

    def __init__(self, tree: ast.AST):
        self.aliases: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    local = alias.asname or alias.name.split(".")[0]
                    full = alias.name if alias.asname else alias.name.split(".")[0]
                    self.aliases[local] = full
            elif isinstance(node, ast.ImportFrom):
                if node.level:  # relative import — module identity unknown
                    continue
                mod = node.module or ""
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    local = alias.asname or alias.name
                    self.aliases[local] = f"{mod}.{alias.name}" if mod else alias.name

    def resolve(self, node: ast.AST) -> Optional[str]:
        dotted = dotted_name(node)
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        base = self.aliases.get(head, head)
        return f"{base}.{rest}" if rest else base


def const_strings(node: ast.AST) -> Set[str]:
    """All string constants anywhere inside *node*."""
    out: Set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            out.add(sub.value)
    return out


def iter_string_constants(node: ast.AST) -> Iterable[ast.Constant]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            yield sub


def call_name_node(call: ast.Call) -> Optional[str]:
    return dotted_name(call.func)


def assigned_names(node: ast.AST) -> Set[str]:
    """Every Name bound by assignment/for/with/comprehension/walrus in *node*.

    Nested function/class defs are included (their names bind locally); the
    bodies of nested defs are still walked, which over-approximates locals —
    acceptable for purity checks (it can only reduce false positives).
    """
    out: Set[str] = set()

    def bind_target(t: ast.AST) -> None:
        # Only actual name bindings: ``x = ...``, ``x, y = ...``, ``*x, = ...``.
        # ``obj.attr = ...`` / ``obj[k] = ...`` mutate an existing object and
        # must NOT mark the root name as locally bound.
        if isinstance(t, ast.Name):
            out.add(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for elt in t.elts:
                bind_target(elt)
        elif isinstance(t, ast.Starred):
            bind_target(t.value)

    for sub in ast.walk(node):
        if isinstance(sub, ast.Assign):
            for t in sub.targets:
                bind_target(t)
        elif isinstance(sub, (ast.AugAssign, ast.AnnAssign)):
            bind_target(sub.target)
        elif isinstance(sub, ast.For):
            bind_target(sub.target)
        elif isinstance(sub, ast.withitem) and sub.optional_vars is not None:
            bind_target(sub.optional_vars)
        elif isinstance(sub, ast.comprehension):
            bind_target(sub.target)
        elif isinstance(sub, ast.NamedExpr):
            bind_target(sub.target)
        elif isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            out.add(sub.name)
        elif isinstance(sub, ast.ExceptHandler) and sub.name:
            out.add(sub.name)
    return out


def module_string_sequences(tree: ast.AST) -> Dict[str, List[str]]:
    """Module-level ``NAME = ("a", "b", ...)`` tuple/list-of-str bindings."""
    out: Dict[str, List[str]] = {}
    body = getattr(tree, "body", [])
    for node in body:
        if not isinstance(node, ast.Assign):
            continue
        value = node.value
        if not isinstance(value, (ast.Tuple, ast.List)):
            continue
        items: List[str] = []
        ok = True
        for elt in value.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                items.append(elt.value)
            else:
                ok = False
                break
        if not ok:
            continue
        for target in node.targets:
            if isinstance(target, ast.Name):
                out[target.id] = items
    return out


def function_params(fn: ast.AST) -> Set[str]:
    """Parameter names of a FunctionDef/AsyncFunctionDef/Lambda."""
    args = getattr(fn, "args", None)
    if args is None:
        return set()
    names: Set[str] = set()
    for group in (args.posonlyargs, args.args, args.kwonlyargs):
        for a in group:
            names.add(a.arg)
    if args.vararg:
        names.add(args.vararg.arg)
    if args.kwarg:
        names.add(args.kwarg.arg)
    return names
