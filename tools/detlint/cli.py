"""detlint command-line interface.

Usage::

    python -m tools.detlint src/                 # text report, exit 1 on new findings
    python -m tools.detlint src/ --format=json   # machine-readable report
    python -m tools.detlint src/ --write-baseline  # grandfather current findings
    python -m tools.detlint --list-rules
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional, Sequence

from .baseline import baseline_counts, load_baseline, write_baseline
from .core import Pass, Report, Rule, run_lint
from .passes.event_coverage import EventCoveragePass
from .passes.registry_coverage import RegistryCoveragePass
from .passes.spec_roundtrip import SpecRoundtripFieldsPass
from .rules.dtypes import DtypeDisciplineRule
from .rules.jit_purity import JitPurityRule
from .rules.rng import NoGlobalRngRule
from .rules.unordered import NoUnorderedFloatAccumulationRule
from .rules.wallclock import NoWallclockRule

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"


def default_rules(ignore_scope: bool = False) -> List[Rule]:
    return [
        NoWallclockRule(ignore_scope=ignore_scope),
        NoGlobalRngRule(),
        NoUnorderedFloatAccumulationRule(),
        JitPurityRule(),
        DtypeDisciplineRule(ignore_scope=ignore_scope),
    ]


def default_passes() -> List[Pass]:
    return [
        EventCoveragePass(),
        RegistryCoveragePass(),
        SpecRoundtripFieldsPass(),
    ]


def build_parser() -> argparse.ArgumentParser:
    parser = argparse.ArgumentParser(
        prog="detlint",
        description="determinism & purity static analysis for this repo",
    )
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to lint (default: src)")
    parser.add_argument("--root", default=".",
                        help="repository root for relative paths (default: cwd)")
    parser.add_argument("--format", choices=("text", "json"), default="text")
    parser.add_argument("--baseline", default=None,
                        help="baseline JSON path (default: tools/detlint/baseline.json)")
    parser.add_argument("--no-baseline", action="store_true",
                        help="ignore the baseline file entirely")
    parser.add_argument("--write-baseline", action="store_true",
                        help="write all current findings to the baseline and exit 0")
    parser.add_argument("--rules", default=None,
                        help="comma-separated rule/pass ids to run (default: all)")
    parser.add_argument("--tests-dir", default=None,
                        help="tests directory for registry-coverage (default: <root>/tests)")
    parser.add_argument("--no-scope", action="store_true",
                        help="treat every file as in scope for every rule "
                             "(fixture/test use)")
    parser.add_argument("--list-rules", action="store_true",
                        help="list rule ids and descriptions, then exit")
    parser.add_argument("--show-all", action="store_true",
                        help="also print suppressed/baselined findings in text mode")
    return parser


def _render_text(report: Report, show_all: bool) -> str:
    lines: List[str] = []
    for f in report.findings:
        if f.status == "new":
            lines.append(f.render())
        elif show_all:
            note = f" ({f.justification})" if f.justification else ""
            lines.append(f"{f.render()} [{f.status}]{note}")
    counts = {}
    for f in report.findings:
        counts[f.status] = counts.get(f.status, 0) + 1
    summary = ", ".join(f"{v} {k}" for k, v in sorted(counts.items())) or "0 findings"
    lines.append(
        f"detlint: {report.files_scanned} files scanned, {summary}"
    )
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = build_parser()
    args = parser.parse_args(argv)

    rules = default_rules(ignore_scope=args.no_scope)
    passes = default_passes()

    if args.list_rules:
        for item in [*rules, *passes]:
            kind = "pass" if isinstance(item, Pass) else "rule"
            print(f"{item.id:36s} [{kind}] {item.description}")
        return 0

    root = Path(args.root)
    baseline_path = Path(args.baseline) if args.baseline else DEFAULT_BASELINE
    only = None
    if args.rules:
        only = {r.strip() for r in args.rules.split(",") if r.strip()}
        known = {item.id for item in [*rules, *passes]}
        unknown = only - known
        if unknown:
            parser.error(f"unknown rules: {', '.join(sorted(unknown))}")

    counts = {}
    if not args.no_baseline and not args.write_baseline:
        counts = baseline_counts(load_baseline(baseline_path))

    report = run_lint(
        paths=[Path(p) for p in args.paths],
        root=root,
        rules=rules,
        passes=passes,
        baseline_counts=counts,
        tests_dir=Path(args.tests_dir) if args.tests_dir else None,
        only=only,
    )

    if args.write_baseline:
        write_baseline(baseline_path, report.findings)
        print(f"detlint: wrote {len(report.new_findings)} findings to {baseline_path}")
        return 0

    if args.format == "json":
        print(json.dumps(report.to_dict(), indent=2, sort_keys=True))
    else:
        print(_render_text(report, show_all=args.show_all))
    return report.exit_code


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
