"""jit-purity: functions handed to jax tracing must be pure.

A function passed to ``jax.jit`` / ``jax.vmap`` / ``jax.lax.scan`` (or a
price-process family ``step`` on a ``vectorized = True`` class) executes
once at trace time; any side effect — mutating closed-over state,
appending to a list, writing through ``self``, I/O — silently happens
once instead of per call and corrupts replay determinism.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Union

from ..astutil import ImportMap, assigned_names, function_params, name_root
from ..core import FileContext, Finding, Rule

FunctionLike = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]

JIT_WRAPPERS = {"jax.jit", "jax.vmap", "jax.pmap"}
SCAN_WRAPPERS = {
    "jax.lax.scan",
    "jax.lax.fori_loop",
    "jax.lax.while_loop",
    "jax.lax.cond",
    "jax.lax.map",
}

MUTATING_METHODS = {
    "append", "extend", "insert", "remove", "pop", "clear",
    "add", "update", "setdefault", "discard",
    "write", "writelines", "sort",
}
IO_BUILTINS = {"print", "open", "input"}


def _resolve_wrapper(resolved: Optional[str]) -> Optional[str]:
    """Map a resolved dotted call name onto a known tracing wrapper."""
    if resolved is None:
        return None
    if resolved in JIT_WRAPPERS or resolved in SCAN_WRAPPERS:
        return resolved
    # `from jax import jit` / `from jax.lax import scan` resolve fully via
    # the import map, but tolerate bare jit/vmap/scan names too (fixtures).
    tail = resolved.rsplit(".", 1)[-1]
    if tail in {"jit", "vmap", "pmap"} and (resolved == tail or "jax" in resolved):
        return f"jax.{tail}"
    if tail in {"scan", "fori_loop", "while_loop", "cond"} and (
        resolved == tail or "lax" in resolved or "jax" in resolved
    ):
        return f"jax.lax.{tail}"
    return None


class _PurityChecker:
    """Inspect one traced function body for side effects."""

    def __init__(self, fn: FunctionLike, is_method: bool = False):
        self.fn = fn
        self.params = function_params(fn)
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        self.locals: Set[str] = set()
        for stmt in body:
            self.locals |= assigned_names(stmt)
        self.locals |= self.params
        # For a vectorized-family step *method*, `self` is the family object:
        # writing through it leaks state across traced steps.
        self.self_is_foreign = is_method and "self" in self.params

    def _root_is_foreign(self, node: ast.AST) -> bool:
        root = name_root(node)
        if root is None:
            return False
        if root == "self":
            return self.self_is_foreign
        return root not in self.locals

    def violations(self) -> List[tuple]:
        out: List[tuple] = []
        body = self.fn.body if isinstance(self.fn.body, list) else [self.fn.body]
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, (ast.Global, ast.Nonlocal)):
                    out.append((node.lineno, node.col_offset,
                                "rebinds global/nonlocal state"))
                elif isinstance(node, ast.Call):
                    func = node.func
                    if isinstance(func, ast.Name) and func.id in IO_BUILTINS \
                            and func.id not in self.locals:
                        out.append((node.lineno, node.col_offset,
                                    f"calls {func.id}() (I/O inside traced code)"))
                    elif isinstance(func, ast.Attribute) \
                            and func.attr in MUTATING_METHODS \
                            and self._root_is_foreign(func.value):
                        root = name_root(func.value) or "<expr>"
                        out.append((node.lineno, node.col_offset,
                                    f"mutates closed-over '{root}' via .{func.attr}()"))
                elif isinstance(node, (ast.Assign, ast.AugAssign)):
                    targets = node.targets if isinstance(node, ast.Assign) else [node.target]
                    for target in targets:
                        if isinstance(target, (ast.Attribute, ast.Subscript)) \
                                and self._root_is_foreign(target):
                            root = name_root(target) or "<expr>"
                            out.append((target.lineno, target.col_offset,
                                        f"writes through closed-over '{root}'"))
        return out


class JitPurityRule(Rule):
    id = "jit-purity"
    description = (
        "functions passed to jax.jit/lax.scan/vmap (and vectorized "
        "price-process family step fns) must not mutate closed-over state, "
        "append to lists, or perform I/O"
    )

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if ctx.tree is None:
            return []
        imports = ImportMap(ctx.tree)

        # Index every function definition in the module by name (scoped
        # resolution is overkill here; last definition wins).
        defs: Dict[str, FunctionLike] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs[node.name] = node

        traced: List[tuple] = []  # (fn, reason, is_method)
        seen: Set[int] = set()

        def mark(fn: Optional[ast.AST], reason: str, is_method: bool = False) -> None:
            if fn is None or not isinstance(
                fn, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
            ):
                return
            if id(fn) in seen:
                return
            seen.add(id(fn))
            traced.append((fn, reason, is_method))

        def resolve_arg(arg: ast.AST) -> Optional[ast.AST]:
            if isinstance(arg, ast.Lambda):
                return arg
            if isinstance(arg, ast.Name):
                return defs.get(arg.id)
            return None

        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                wrapper = _resolve_wrapper(imports.resolve(node.func))
                if wrapper is not None and node.args:
                    mark(resolve_arg(node.args[0]), wrapper)
                    continue
                # functools.partial(jax.jit, ...) — treat like a decorator use
                resolved = imports.resolve(node.func)
                if resolved == "functools.partial" and node.args:
                    inner = _resolve_wrapper(imports.resolve(node.args[0]))
                    if inner is not None and len(node.args) > 1:
                        mark(resolve_arg(node.args[1]), inner)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    target = dec.func if isinstance(dec, ast.Call) else dec
                    wrapper = _resolve_wrapper(imports.resolve(target))
                    if wrapper is None and isinstance(dec, ast.Call):
                        # @partial(jax.jit, static_argnums=...)
                        resolved = imports.resolve(dec.func)
                        if resolved == "functools.partial" and dec.args:
                            wrapper = _resolve_wrapper(imports.resolve(dec.args[0]))
                    if wrapper is not None:
                        mark(node, wrapper)
                        break
            elif isinstance(node, ast.ClassDef):
                # Price-process families: classes with `vectorized = True`
                # have their step() traced inside jitted/scan code.
                is_vectorized = any(
                    isinstance(stmt, ast.Assign)
                    and any(
                        isinstance(t, ast.Name) and t.id == "vectorized"
                        for t in stmt.targets
                    )
                    and isinstance(stmt.value, ast.Constant)
                    and stmt.value.value is True
                    for stmt in node.body
                )
                if is_vectorized:
                    for stmt in node.body:
                        if isinstance(stmt, ast.FunctionDef) and stmt.name in {
                            "step", "init", "pack"
                        }:
                            mark(stmt, f"vectorized family {node.name}.{stmt.name}",
                                 is_method=True)

        findings: List[Finding] = []
        for fn, reason, is_method in traced:
            for lineno, col, what in _PurityChecker(fn, is_method).violations():
                name = getattr(fn, "name", "<lambda>")
                findings.append(
                    Finding(
                        rule=self.id,
                        path=ctx.rel,
                        line=lineno,
                        col=col,
                        message=(
                            f"impure traced function '{name}' ({reason}): {what} — "
                            "traced code runs once at trace time, so side effects "
                            "do not replay"
                        ),
                    )
                )
        return findings
