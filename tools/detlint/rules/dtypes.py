"""dtype-discipline: explicit dtypes at the packed-array boundary.

The packed ``MarketState`` pytree, the engine's registry columns, and the
host-accounting arrays cross the numpy<->jax boundary.  numpy defaults to
float64 while jax defaults to float32 (unless x64 is enabled), so a bare
``np.zeros(n)`` seeds an implicit f32/f64 mix the moment the array crosses
over — every constructor at this boundary must pass an explicit ``dtype=``.
"""

from __future__ import annotations

import ast
from typing import Iterable, List

from ..astutil import ImportMap
from ..core import FileContext, Finding, Rule

# numpy/jnp constructors whose dtype defaults are backend-dependent.
CONSTRUCTORS = {
    "numpy.zeros", "numpy.ones", "numpy.empty", "numpy.full",
    "numpy.array", "numpy.asarray", "numpy.arange", "numpy.linspace",
    "numpy.zeros_like", "numpy.ones_like", "numpy.empty_like", "numpy.full_like",
    "jax.numpy.zeros", "jax.numpy.ones", "jax.numpy.empty", "jax.numpy.full",
    "jax.numpy.array", "jax.numpy.asarray", "jax.numpy.arange", "jax.numpy.linspace",
}

# *_like constructors inherit their prototype's dtype — that IS explicit.
LIKE_CONSTRUCTORS = {c for c in CONSTRUCTORS if c.endswith("_like")}

# Positional dtype slots: np.array(obj, dtype), np.asarray(a, dtype),
# np.full(shape, fill, dtype), np.zeros(shape, dtype), ...
POSITIONAL_DTYPE_INDEX = {
    "numpy.zeros": 1, "numpy.ones": 1, "numpy.empty": 1,
    "numpy.array": 1, "numpy.asarray": 1, "numpy.full": 2,
    "jax.numpy.zeros": 1, "jax.numpy.ones": 1, "jax.numpy.empty": 1,
    "jax.numpy.array": 1, "jax.numpy.asarray": 1, "jax.numpy.full": 2,
}

# The boundary files: packed MarketState construction (price_process),
# registry columns + history (engine), and host accounting arrays (hosts).
SCOPED_FILES = (
    "src/repro/market/price_process.py",
    "src/repro/market/engine.py",
    "src/repro/core/hosts.py",
    "src/repro/serve/autoscale.py",
    "src/repro/serve/demand.py",
    "src/repro/serve/service.py",
    "src/repro/serve/slo.py",
)


class DtypeDisciplineRule(Rule):
    id = "dtype-discipline"
    description = (
        "array constructors at the packed MarketState / registry-column "
        "boundary must pass an explicit dtype (numpy f64 vs jax f32 defaults "
        "silently mix precisions)"
    )

    def __init__(self, ignore_scope: bool = False):
        self.ignore_scope = ignore_scope

    def in_scope(self, rel: str) -> bool:
        if self.ignore_scope:
            return True
        return rel in SCOPED_FILES

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if ctx.tree is None or not self.in_scope(ctx.rel):
            return []
        imports = ImportMap(ctx.tree)
        findings: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            resolved = imports.resolve(node.func)
            if resolved not in CONSTRUCTORS or resolved in LIKE_CONSTRUCTORS:
                continue
            has_dtype = any(kw.arg == "dtype" for kw in node.keywords)
            pos = POSITIONAL_DTYPE_INDEX.get(resolved)
            if pos is not None and len(node.args) > pos:
                has_dtype = True
            if not has_dtype:
                short = resolved.replace("numpy", "np").replace("jax.np", "jnp")
                findings.append(
                    Finding(
                        rule=self.id,
                        path=ctx.rel,
                        line=node.lineno,
                        col=node.col_offset,
                        message=(
                            f"{short}(...) without an explicit dtype at the "
                            "packed-array boundary — numpy defaults to float64, "
                            "jax to float32; pass dtype= explicitly"
                        ),
                    )
                )
        return findings
