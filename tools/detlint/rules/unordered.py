"""no-unordered-float-accumulation: set-iteration into float sums.

Float addition is not associative; summing over a container whose
iteration order is unspecified (sets, frozensets, set-algebra results)
produces run-to-run different low bits and breaks bit-identity.  Dicts
are insertion-ordered in CPython >= 3.7 and are deliberately *not*
flagged.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Optional

from ..core import FileContext, Finding, Rule

_SET_METHODS = {"union", "intersection", "difference", "symmetric_difference"}


def _is_setish(node: ast.AST) -> bool:
    """Conservatively: does this expression evaluate to a set?"""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        func = node.func
        if isinstance(func, ast.Name) and func.id in {"set", "frozenset"}:
            return True
        if isinstance(func, ast.Attribute) and func.attr in _SET_METHODS:
            return True
    if isinstance(node, ast.BinOp) and isinstance(node.op, (ast.BitAnd, ast.BitOr, ast.Sub)):
        # set algebra via operators: a & b, a | b, a - b on set operands
        return _is_setish(node.left) or _is_setish(node.right)
    return False


def _setish_iter_of(node: ast.AST) -> Optional[ast.AST]:
    """If *node* is a comprehension/genexp over a set-ish iterable, return it."""
    if isinstance(node, (ast.GeneratorExp, ast.ListComp)):
        for gen in node.generators:
            if _is_setish(gen.iter):
                return gen.iter
    return None


class NoUnorderedFloatAccumulationRule(Rule):
    id = "no-unordered-float-accumulation"
    description = (
        "no iterating a set into a float sum or accumulation loop "
        "(unordered iteration makes float addition order unstable)"
    )

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if ctx.tree is None:
            return []
        findings: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                func = node.func
                # math.fsum is correctly rounded regardless of order, and
                # max/min are order-independent — only builtin sum() is an
                # order-sensitive float accumulator.
                is_sum = isinstance(func, ast.Name) and func.id == "sum"
                if is_sum and node.args:
                    arg = node.args[0]
                    if _is_setish(arg) or _setish_iter_of(arg) is not None:
                        findings.append(
                            Finding(
                                rule=self.id,
                                path=ctx.rel,
                                line=node.lineno,
                                col=node.col_offset,
                                message=(
                                    "float sum over an unordered set iteration — "
                                    "sort the elements (or accumulate over an "
                                    "ordered sequence) to keep bit-identity"
                                ),
                            )
                        )
            elif isinstance(node, ast.For) and _is_setish(node.iter):
                has_augadd = any(
                    isinstance(sub, ast.AugAssign) and isinstance(sub.op, ast.Add)
                    for stmt in node.body
                    for sub in ast.walk(stmt)
                )
                if has_augadd:
                    findings.append(
                        Finding(
                            rule=self.id,
                            path=ctx.rel,
                            line=node.lineno,
                            col=node.col_offset,
                            message=(
                                "accumulation loop over an unordered set — "
                                "iterate sorted(...) to keep float accumulation "
                                "order stable"
                            ),
                        )
                    )
        return findings
