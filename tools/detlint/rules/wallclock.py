"""no-wallclock: sim paths must not read wall clocks.

Bit-identity contracts (vectorized == scalar oracle, serial == parallel
sweeps, log-on == log-off) require that nothing inside the simulation
core depends on real time.  Only observability (`obs/`), launch-layer
progress reporting, and benchmarks may read clocks.
"""

from __future__ import annotations

import ast
from typing import Iterable, List

from ..astutil import ImportMap
from ..core import FileContext, Finding, Rule

FORBIDDEN_CALLS = {
    "time.time",
    "time.time_ns",
    "time.perf_counter",
    "time.perf_counter_ns",
    "time.monotonic",
    "time.monotonic_ns",
    "time.process_time",
    "time.process_time_ns",
    "datetime.datetime.now",
    "datetime.datetime.today",
    "datetime.datetime.utcnow",
    "datetime.date.today",
}

# Clock reads are a hazard only inside the deterministic sim core.  obs/,
# launch/, elastic/ (checkpoint wall stamps) and benchmarks are wall-time
# consumers by design.  The serve/ closed loop runs on sim time only.
SCOPED_PREFIXES = (
    "src/repro/core/",
    "src/repro/market/",
    "src/repro/api/",
    "src/repro/serve/",
)


class NoWallclockRule(Rule):
    id = "no-wallclock"
    description = (
        "no time.time/perf_counter/datetime.now in src/repro/{core,market,api} "
        "sim paths (only obs/ and benchmarks/ may read clocks)"
    )

    def __init__(self, ignore_scope: bool = False):
        self.ignore_scope = ignore_scope

    def in_scope(self, rel: str) -> bool:
        if self.ignore_scope:
            return True
        return rel.startswith(SCOPED_PREFIXES)

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if ctx.tree is None or not self.in_scope(ctx.rel):
            return []
        imports = ImportMap(ctx.tree)
        findings: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            resolved = imports.resolve(node.func)
            if resolved in FORBIDDEN_CALLS:
                findings.append(
                    Finding(
                        rule=self.id,
                        path=ctx.rel,
                        line=node.lineno,
                        col=node.col_offset,
                        message=(
                            f"wall-clock read {resolved}() in a sim path — "
                            "sim code must be a pure function of (spec, seed); "
                            "only obs/ and benchmarks/ may read clocks"
                        ),
                    )
                )
        return findings
