"""no-global-rng: forbid global / legacy RNG entry points.

All randomness must flow from explicitly threaded, seeded
``np.random.default_rng(...)`` Generators (or functional ``jax.random``
keys) so that every run is a pure function of its seed.  The stdlib
``random`` module and the legacy ``np.random.*`` module-level functions
share hidden global state and break the serial==parallel sweep contract.
"""

from __future__ import annotations

import ast
from typing import Iterable, List

from ..astutil import ImportMap
from ..core import FileContext, Finding, Rule

# Constructors of explicitly seeded generator objects are the approved API.
NUMPY_ALLOWED = {
    "default_rng",
    "Generator",
    "SeedSequence",
    "BitGenerator",
    "PCG64",
    "PCG64DXSM",
    "Philox",
    "SFC64",
    "MT19937",
}

# random.Random(seed) is an explicitly seeded instance; everything else on
# the stdlib module (including SystemRandom — os-entropy) is forbidden.
STDLIB_ALLOWED = {"random.Random"}


class NoGlobalRngRule(Rule):
    id = "no-global-rng"
    description = (
        "no stdlib random.* or legacy np.random.* module-level calls; "
        "thread seeded np.random.default_rng Generators explicitly"
    )

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if ctx.tree is None:
            return []
        imports = ImportMap(ctx.tree)
        findings: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            resolved = imports.resolve(node.func)
            if resolved is None:
                continue
            bad = None
            if resolved.startswith("random.") and resolved not in STDLIB_ALLOWED:
                bad = (
                    f"stdlib {resolved}() uses hidden global RNG state — "
                    "thread a seeded np.random.default_rng(...) Generator instead"
                )
            elif resolved.startswith("numpy.random."):
                attr = resolved[len("numpy.random."):]
                if attr.split(".")[0] not in NUMPY_ALLOWED:
                    bad = (
                        f"legacy np.random.{attr}() touches module-global RNG "
                        "state — use an explicitly threaded "
                        "np.random.default_rng(...) Generator"
                    )
            if bad is not None:
                findings.append(
                    Finding(
                        rule=self.id,
                        path=ctx.rel,
                        line=node.lineno,
                        col=node.col_offset,
                        message=bad,
                    )
                )
        return findings
