from .dtypes import DtypeDisciplineRule
from .jit_purity import JitPurityRule
from .rng import NoGlobalRngRule
from .unordered import NoUnorderedFloatAccumulationRule
from .wallclock import NoWallclockRule

__all__ = [
    "DtypeDisciplineRule",
    "JitPurityRule",
    "NoGlobalRngRule",
    "NoUnorderedFloatAccumulationRule",
    "NoWallclockRule",
]
