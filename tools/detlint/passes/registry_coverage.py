"""registry-coverage: every registered plugin name is reachable and tested.

The spec layer (PR 4) resolves policies, bid strategies, migration
planners, price processes, workloads, fleet strategies, and fault
scenarios by string name through plugin registries.  A name registered
but never referenced by a test is dead weight that can silently rot; a
registry not wired into the spec layer is unreachable from a declarative
run.  This pass:

* collects every registration site (decorator or ``REGISTRY.register``
  call), resolving loop-variable names through module-level string
  tuples (the migration planners register in a loop);
* requires each registered name to appear as a quoted literal in at
  least one test file;
* flags duplicate registrations of the same name in a registry;
* requires each registry symbol to be referenced from its spec-layer
  anchor module, so every plugin stays constructible from a spec.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from ..astutil import module_string_sequences
from ..core import FileContext, Finding, Pass, Project

# decorator/function name -> registry label
REGISTER_HELPERS = {
    "register_policy": "POLICY",
    "register_bid_strategy": "BID",
    "register_migration_policy": "MIGRATION",
    "register_price_process": "PRICE_PROCESS",
    "register_workload": "WORKLOAD",
    "register_fleet_strategy": "FLEET_STRATEGY",
    "register_fault_scenario": "FAULT",
    "register_autoscale_policy": "AUTOSCALE",
}

# registry variable name -> registry label (for REGISTRY.register(...) calls)
REGISTRY_VARS = {
    "POLICY_REGISTRY": "POLICY",
    "BID_REGISTRY": "BID",
    "MIGRATION_REGISTRY": "MIGRATION",
    "PRICE_PROCESS_REGISTRY": "PRICE_PROCESS",
    "WORKLOAD_REGISTRY": "WORKLOAD",
    "FLEET_STRATEGY_REGISTRY": "FLEET_STRATEGY",
    "FAULT_REGISTRY": "FAULT",
    "AUTOSCALE_REGISTRY": "AUTOSCALE",
}

# Where each registry must surface to be constructible from a spec: the
# spec layer itself for most, the market engine for price processes
# (PoolConfig.process names resolve there).
SPEC_ANCHORS = {
    "POLICY": ("repro/api/specs.py", "POLICY_REGISTRY"),
    "BID": ("repro/api/specs.py", "BID_REGISTRY"),
    "MIGRATION": ("repro/api/specs.py", "MIGRATION_REGISTRY"),
    "WORKLOAD": ("repro/api/specs.py", "WORKLOAD_REGISTRY"),
    "FLEET_STRATEGY": ("repro/api/specs.py", "FLEET_STRATEGY_REGISTRY"),
    "FAULT": ("repro/api/specs.py", "FAULT_REGISTRY"),
    "PRICE_PROCESS": ("repro/market/engine.py", "PRICE_PROCESS_REGISTRY"),
    "AUTOSCALE": ("repro/api/specs.py", "AUTOSCALE_REGISTRY"),
}


def _helper_label(func: ast.AST) -> Optional[str]:
    """Registry label for a decorator/call target, or None."""
    name = None
    if isinstance(func, ast.Name):
        name = func.id
    elif isinstance(func, ast.Attribute):
        name = func.attr
    if name in REGISTER_HELPERS:
        return REGISTER_HELPERS[name]
    return None


def _registry_var_label(func: ast.AST) -> Optional[str]:
    """Label for ``<REGISTRY_VAR>.register`` call targets."""
    if isinstance(func, ast.Attribute) and func.attr == "register":
        base = func.value
        if isinstance(base, ast.Name) and base.id in REGISTRY_VARS:
            return REGISTRY_VARS[base.id]
        if isinstance(base, ast.Attribute) and base.attr in REGISTRY_VARS:
            return REGISTRY_VARS[base.attr]
    return None


def _loop_var_values(ctx: FileContext, var: str) -> List[str]:
    """Resolve a name used inside a for-loop over a module string tuple.

    Handles the migration-planner idiom::

        MIGRATION_POLICIES = ("none", "greedy-cheapest", ...)
        for _policy in MIGRATION_POLICIES:
            MIGRATION_REGISTRY.register(_policy, _builtin_planner(_policy))
    """
    if ctx.tree is None:
        return []
    sequences = module_string_sequences(ctx.tree)
    values: List[str] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.For):
            continue
        target = node.target
        if isinstance(target, ast.Name) and target.id == var:
            it = node.iter
            if isinstance(it, ast.Name) and it.id in sequences:
                values.extend(sequences[it.id])
            elif isinstance(it, (ast.Tuple, ast.List)):
                for elt in it.elts:
                    if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                        values.append(elt.value)
    return values


class RegistryCoveragePass(Pass):
    id = "registry-coverage"
    description = (
        "every registered plugin name is test-referenced and unique; every "
        "registry is wired into the spec layer"
    )

    def check(self, project: Project) -> Iterable[Finding]:
        findings: List[Finding] = []
        # (label, name) -> list of (rel, line)
        registrations: Dict[Tuple[str, str], List[Tuple[str, int]]] = {}
        seen_registries: Set[str] = set()

        for ctx in project.files:
            if ctx.tree is None:
                continue
            # Registration helpers are themselves implemented as
            # ``def register_x(name): REGISTRY.register(name, ...)`` — a call
            # whose name argument is a parameter of an enclosing function is
            # the helper's plumbing, not a registration site.
            enclosing_params: Dict[int, Set[str]] = {}

            def _index_params(fn: ast.AST, inherited: Set[str]) -> None:
                from ..astutil import function_params

                params = inherited | function_params(fn)
                for sub in ast.walk(fn):
                    if isinstance(sub, ast.Call):
                        enclosing_params.setdefault(id(sub), set()).update(params)

            for node in ast.walk(ctx.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    _index_params(node, set())

            # Decorator calls are reached twice by ast.walk (once via the
            # FunctionDef's decorator_list, once as plain Call nodes) — a
            # single sweep over Call nodes sees each site exactly once.
            for node in ast.walk(ctx.tree):
                if not isinstance(node, ast.Call):
                    continue
                label = _helper_label(node.func) or _registry_var_label(node.func)
                if label is None:
                    continue
                seen_registries.add(label)
                if not node.args:
                    continue
                arg = node.args[0]
                names: List[str] = []
                if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                    names = [arg.value]
                elif isinstance(arg, ast.Name):
                    if arg.id in enclosing_params.get(id(node), set()):
                        continue  # helper plumbing, not a registration
                    names = _loop_var_values(ctx, arg.id)
                    if not names:
                        findings.append(Finding(
                            rule=self.id, path=ctx.rel,
                            line=node.lineno, col=node.col_offset,
                            message=(
                                f"{label} registration with non-literal name "
                                f"'{arg.id}' that does not resolve to a "
                                "module-level string tuple — name cannot be "
                                "statically audited"
                            ),
                        ))
                for name in names:
                    registrations.setdefault((label, name), []).append(
                        (ctx.rel, node.lineno)
                    )

        if not registrations:
            return findings  # not scanning the src tree (fixture run)

        # --- duplicates -----------------------------------------------------
        for (label, name), sites in sorted(registrations.items()):
            if len(sites) > 1:
                first_rel, first_line = sites[0]
                others = ", ".join(f"{r}:{ln}" for r, ln in sites[1:])
                findings.append(Finding(
                    rule=self.id, path=first_rel, line=first_line, col=0,
                    message=f"{label} name '{name}' registered more than once "
                            f"(also at {others}) — later registration silently "
                            "shadows this one",
                ))

        # --- test references ------------------------------------------------
        test_blobs = [src for _, src in project.test_sources()]
        for (label, name), sites in sorted(registrations.items()):
            quoted = (f'"{name}"', f"'{name}'")
            if not any(q in blob for blob in test_blobs for q in quoted):
                rel, line = sites[0]
                findings.append(Finding(
                    rule=self.id, path=rel, line=line, col=0,
                    message=f"{label} name '{name}' is not referenced by any "
                            "test — registered plugins must be exercised by at "
                            "least one test",
                ))

        # --- spec-layer wiring ----------------------------------------------
        for label in sorted(seen_registries):
            anchor = SPEC_ANCHORS.get(label)
            if anchor is None:
                continue
            suffix, symbol = anchor
            anchor_ctx = project.find(suffix)
            if anchor_ctx is None:
                continue  # anchor outside scan scope
            if symbol not in anchor_ctx.source:
                findings.append(Finding(
                    rule=self.id, path=anchor_ctx.rel, line=1, col=0,
                    message=f"{label} registry ({symbol}) is not referenced from "
                            f"{suffix} — registered names are not constructible "
                            "from a spec",
                ))
        return findings
