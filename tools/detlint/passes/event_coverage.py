"""event-coverage: every event kind is fully wired, end to end.

Two vocabularies must stay consistent:

* ``EventKind`` (core/events.py) — the simulator's heap-event enum.  Every
  member needs a PRIORITY entry, a ``_dispatch`` handler branch in
  core/simulator.py, and at least one push site.
* ``LogEventKind`` (obs/eventlog.py) — the flight-recorder vocabulary.
  Every enum value must be emitted somewhere in src/ and every emitted
  string literal must be a declared enum value (no half-wired kinds).

The pass also asserts the traced dispatch label ("dispatch/<kind>") is
still constructed in the simulator, so tracer coverage cannot silently
rot.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from ..astutil import dotted_name
from ..core import Finding, Pass, Project

EVENTS_SUFFIX = "repro/core/events.py"
SIMULATOR_SUFFIX = "repro/core/simulator.py"
EVENTLOG_SUFFIX = "repro/obs/eventlog.py"


def _enum_members(tree: ast.AST, class_name: str) -> Dict[str, Tuple[str, int]]:
    """``member -> (string value, lineno)`` for a str-valued enum class."""
    out: Dict[str, Tuple[str, int]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == class_name:
            for stmt in node.body:
                if (
                    isinstance(stmt, ast.Assign)
                    and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)
                    and isinstance(stmt.value, ast.Constant)
                    and isinstance(stmt.value.value, str)
                ):
                    out[stmt.targets[0].id] = (stmt.value.value, stmt.lineno)
            return out
    return out


def _priority_keys(tree: ast.AST) -> Set[str]:
    """EventKind members keyed in the module-level PRIORITY dict."""
    keys: Set[str] = set()
    for node in getattr(tree, "body", []):
        if (
            isinstance(node, ast.Assign)
            and len(node.targets) == 1
            and isinstance(node.targets[0], ast.Name)
            and node.targets[0].id == "PRIORITY"
            and isinstance(node.value, ast.Dict)
        ):
            for key in node.value.keys:
                name = dotted_name(key) if key is not None else None
                if name and name.startswith("EventKind."):
                    keys.add(name.split(".", 1)[1])
    return keys


def _eventkind_refs(node: ast.AST) -> Set[str]:
    """All ``EventKind.X`` member references inside *node*."""
    refs: Set[str] = set()
    for sub in ast.walk(node):
        name = dotted_name(sub)
        if name and name.startswith("EventKind."):
            refs.add(name.split(".", 1)[1])
    return refs


def _find_function(tree: ast.AST, name: str) -> Optional[ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def _emit_kind_literals(tree: ast.AST) -> List[Tuple[str, int]]:
    """String literals used as the kind argument of ``*.emit(t, kind, ...)``.

    Handles conditional kinds (``"resume" if resumed else "start"``) by
    collecting every string constant reachable in the kind expression.
    """
    out: List[Tuple[str, int]] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        if not (isinstance(func, ast.Attribute) and func.attr == "emit"):
            continue
        kind_expr: Optional[ast.AST] = None
        if len(node.args) >= 2:
            kind_expr = node.args[1]
        else:
            for kw in node.keywords:
                if kw.arg == "kind":
                    kind_expr = kw.value
        if kind_expr is None:
            continue
        for sub in ast.walk(kind_expr):
            if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                out.append((sub.value, node.lineno))
    return out


class EventCoveragePass(Pass):
    id = "event-coverage"
    description = (
        "every EventKind has a PRIORITY entry and a simulator dispatch "
        "handler; every LogEventKind is emitted and every emit uses a "
        "declared kind; the traced dispatch label survives"
    )

    def check(self, project: Project) -> Iterable[Finding]:
        findings: List[Finding] = []

        events = project.find(EVENTS_SUFFIX)
        simulator = project.find(SIMULATOR_SUFFIX)
        eventlog = project.find(EVENTLOG_SUFFIX)
        if events is None or events.tree is None:
            return findings  # not scanning the sim tree (e.g. fixture run)

        members = _enum_members(events.tree, "EventKind")
        class_line = next(iter(members.values()), ("", 1))[1]

        # --- EventKind <-> PRIORITY bijection -------------------------------
        priority = _priority_keys(events.tree)
        for member, (_, lineno) in sorted(members.items()):
            if member not in priority:
                findings.append(Finding(
                    rule=self.id, path=events.rel, line=lineno, col=0,
                    message=f"EventKind.{member} has no PRIORITY entry — "
                            "same-timestamp ordering is undefined for it",
                ))
        for member in sorted(priority - set(members)):
            findings.append(Finding(
                rule=self.id, path=events.rel, line=class_line, col=0,
                message=f"PRIORITY keys unknown member EventKind.{member}",
            ))

        # --- every member dispatched and pushed -----------------------------
        if simulator is not None and simulator.tree is not None:
            dispatch = _find_function(simulator.tree, "_dispatch")
            handled = _eventkind_refs(dispatch) if dispatch is not None else set()
            pushed: Set[str] = set()
            for ctx in project.files:
                if ctx.tree is None:
                    continue
                for node in ast.walk(ctx.tree):
                    if isinstance(node, ast.Call):
                        func = node.func
                        if isinstance(func, ast.Attribute) and func.attr in {
                            "push", "push_event", "schedule"
                        }:
                            pushed |= _eventkind_refs(node)
            for member, (_, lineno) in sorted(members.items()):
                if member not in handled:
                    findings.append(Finding(
                        rule=self.id, path=events.rel, line=lineno, col=0,
                        message=f"EventKind.{member} has no handler branch in "
                                "simulator._dispatch — the kind is declared but "
                                "never serviced",
                    ))
                if member not in pushed:
                    findings.append(Finding(
                        rule=self.id, path=events.rel, line=lineno, col=0,
                        message=f"EventKind.{member} is never pushed onto the "
                                "event heap anywhere in the scanned tree — "
                                "dead event kind",
                    ))
            if "dispatch/" not in simulator.source:
                findings.append(Finding(
                    rule=self.id, path=simulator.rel, line=1, col=0,
                    message="traced per-kind dispatch label ('dispatch/<kind>') "
                            "is gone from the simulator — tracer coverage of "
                            "event dispatch lost",
                ))

        # --- LogEventKind <-> emit-site vocabulary --------------------------
        if eventlog is not None and eventlog.tree is not None:
            log_members = _enum_members(eventlog.tree, "LogEventKind")
            log_values = {v for v, _ in log_members.values()}
            if not log_values:
                findings.append(Finding(
                    rule=self.id, path=eventlog.rel, line=1, col=0,
                    message="LogEventKind enum not found in obs/eventlog.py — "
                            "the log-kind vocabulary is undeclared",
                ))
            else:
                emitted: Dict[str, Tuple[str, int]] = {}
                for ctx in project.files:
                    if ctx.tree is None or ctx.rel.endswith(EVENTLOG_SUFFIX):
                        continue
                    for value, lineno in _emit_kind_literals(ctx.tree):
                        emitted.setdefault(value, (ctx.rel, lineno))
                for value in sorted(set(emitted) - log_values):
                    rel, lineno = emitted[value]
                    findings.append(Finding(
                        rule=self.id, path=rel, line=lineno, col=0,
                        message=f"emit of undeclared log kind '{value}' — add it "
                                "to LogEventKind so validation and analytics "
                                "know about it",
                    ))
                for value in sorted(log_values - set(emitted)):
                    member_line = next(
                        (ln for v, ln in log_members.values() if v == value), 1
                    )
                    findings.append(Finding(
                        rule=self.id, path=eventlog.rel, line=member_line, col=0,
                        message=f"LogEventKind '{value}' has no emit site in the "
                                "scanned tree — half-wired log kind",
                    ))
        return findings
