from .event_coverage import EventCoveragePass
from .registry_coverage import RegistryCoveragePass
from .spec_roundtrip import SpecRoundtripFieldsPass

__all__ = [
    "EventCoveragePass",
    "RegistryCoveragePass",
    "SpecRoundtripFieldsPass",
]
