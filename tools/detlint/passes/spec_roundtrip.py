"""spec-roundtrip-fields: every *Spec dataclass field round-trips.

The declarative run layer (PR 4) serializes every ``*Spec`` dataclass
through hand-written ``to_dict``/``from_dict`` pairs.  A field added to
the dataclass but missed in either method silently drops configuration
on save/load — sweeps resume with different parameters than they started
with.  This pass requires every dataclass field name to appear as a
string literal in both methods.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Optional

from ..astutil import const_strings, dotted_name
from ..core import Finding, Pass, Project


def _is_dataclass(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = dotted_name(target)
        if name and name.split(".")[-1] == "dataclass":
            return True
    return False


def _method(node: ast.ClassDef, name: str) -> Optional[ast.FunctionDef]:
    for stmt in node.body:
        if isinstance(stmt, ast.FunctionDef) and stmt.name == name:
            return stmt
    return None


def _annotation_is_classvar(ann: ast.AST) -> bool:
    text = ast.dump(ann)
    return "ClassVar" in text


class SpecRoundtripFieldsPass(Pass):
    id = "spec-roundtrip-fields"
    description = (
        "every field of a *Spec dataclass appears as a string literal in "
        "both its to_dict and from_dict"
    )

    def check(self, project: Project) -> Iterable[Finding]:
        findings: List[Finding] = []
        for ctx in project.files:
            if ctx.tree is None:
                continue
            for node in ast.walk(ctx.tree):
                if not isinstance(node, ast.ClassDef):
                    continue
                if not node.name.endswith("Spec") or not _is_dataclass(node):
                    continue
                to_dict = _method(node, "to_dict")
                from_dict = _method(node, "from_dict")
                if to_dict is None or from_dict is None:
                    # Specs inheriting shared round-trip machinery are out of
                    # scope for a per-class literal check.
                    continue
                to_strings = const_strings(to_dict)
                from_strings = const_strings(from_dict)
                for stmt in node.body:
                    if not isinstance(stmt, ast.AnnAssign):
                        continue
                    target = stmt.target
                    if not isinstance(target, ast.Name):
                        continue
                    field = target.id
                    if field.startswith("_") or _annotation_is_classvar(stmt.annotation):
                        continue
                    missing = []
                    if field not in to_strings:
                        missing.append("to_dict")
                    if field not in from_strings:
                        missing.append("from_dict")
                    if missing:
                        findings.append(Finding(
                            rule=self.id, path=ctx.rel,
                            line=stmt.lineno, col=stmt.col_offset,
                            message=(
                                f"{node.name}.{field} does not appear in "
                                f"{' or '.join(missing)} — the field will be "
                                "dropped on spec round-trip"
                            ),
                        ))
        return findings
