PY ?= python

.PHONY: lint lint-json baseline test sanitize-smoke

lint:
	$(PY) -m tools.detlint src/

lint-json:
	$(PY) -m tools.detlint src/ --format=json

baseline:
	$(PY) -m tools.detlint src/ --write-baseline

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

sanitize-smoke:
	PYTHONPATH=src $(PY) -m repro.launch.market_sim --market \
	  --regimes volatile --policy first-fit --until 3600 --sanitize
