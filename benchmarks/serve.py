"""Serving-scenario benchmarks (PR 10).

* ``serve/autoscale_tick`` — one autoscaler control decision (signal
  assembly excluded): the target-tracking policy plus
  hysteresis/cooldown damping over a batch of synthetic demand signals.
* ``serve/request_throughput`` — end-to-end serving closed loop: a
  diurnal-demand run through the spec/build stack (demand integration,
  per-VM request schedulers, autoscaler cadence), reported as wall
  microseconds per served request.
"""
from __future__ import annotations

import time

from repro.api import (
    AutoscaleSpec,
    FleetSpec,
    PolicySpec,
    RunSpec,
    ScenarioSpec,
    ServeSpec,
    build,
)
from repro.serve.autoscale import Autoscaler, AutoscaleConfig, DemandSignals

from .common import emit, timeit


def bench_autoscale_tick(n_signals: int):
    sigs = [
        DemandSignals(t=300.0 * i, rate_ewma=0.1 + 0.01 * (i % 40),
                      queue_depth=i % 23, p95_latency=30.0 + (i % 7),
                      live_units=4 + i % 9, target_units=4 + i % 9,
                      unit_throughput=0.0333, rate_ahead=0.12)
        for i in range(n_signals)
    ]

    def decide_all():
        a = Autoscaler("target-tracking",
                       AutoscaleConfig(cooldown=0.0, hysteresis=0.1))
        for s in sigs:
            a.decide(s)

    t = timeit(decide_all, n=9) / n_signals
    return [emit("serve/autoscale_tick", t,
                 f"signals={n_signals};policy=target-tracking")]


def bench_request_throughput(horizon: float):
    spec = RunSpec(
        scenario=ScenarioSpec(workload="serve-diurnal", regime="volatile",
                              n_pools=4, horizon=horizon,
                              workload_params={"base_rate": 0.3,
                                               "amplitude": 0.1}),
        policy=PolicySpec("first-fit"),
        fleet=FleetSpec(params={"target_capacity": 24.0}),
        serve=ServeSpec(),
        autoscale=AutoscaleSpec("target-tracking",
                                params={"cadence": 300.0, "max_units": 24}))
    sim = build(spec, seed=0)
    t0 = time.time()
    metrics = sim.run(until=horizon)
    wall = time.time() - t0
    done = max(metrics.requests_done, 1)
    return [emit("serve/request_throughput", wall * 1e6 / done,
                 f"horizon={horizon:.0f};done={metrics.requests_done};"
                 f"wall_s={wall:.2f}")]


def run(quick: bool = True):
    rows = []
    rows += bench_autoscale_tick(2000 if quick else 20000)
    rows += bench_request_throughput(7200.0 if quick else 43200.0)
    return rows
