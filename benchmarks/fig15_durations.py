"""Fig. 15 — spot interruption durations (avg / max / min) per policy.

Paper §VII-E3: HLEM-VMP best average; adjusted HLEM-VMP best maximum."""
from __future__ import annotations

from repro.core import ScenarioConfig

from .common import emit, run_market

POLICIES = ["first-fit", "hlem-vmp", "hlem-vmp-adjusted"]


def run(quick: bool = True):
    rows = []
    for pol in POLICIES:
        sim, metrics, wall = run_market(pol, ScenarioConfig(seed=0))
        s = metrics.spot_stats(sim.vms)
        rows.append(emit(
            f"fig15/{pol}", wall * 1e6 / max(metrics.allocations, 1),
            f"avg_s={s['avg_interruption_time']:.2f};"
            f"max_s={s['max_interruption_time']:.2f};"
            f"min_s={s['min_interruption_time']:.2f}"))
    return rows
