"""Cross-PR benchmark trajectory: aggregate committed ``BENCH_*.json``
artifacts into one per-metric history table.

  python -m benchmarks.history                       # print markdown
  python -m benchmarks.history --out results/bench/TRAJECTORY.md

Every PR commits a ``results/bench/BENCH_<label>.json`` snapshot (see
``benchmarks/run.py``); this module lines their ``us_per_call`` rows up
side by side so a metric's drift across the PR sequence is one glance —
the complement to ``check_regression``'s pairwise CI gate.  Labels are
ordered ``seed`` first, then ``prN`` numerically, then anything else
alphabetically; metrics appear in first-seen order grouped by their
``<group>/`` prefix.  Cells are blank where an artifact predates the
metric (benchmarks accrete with the subsystems they measure).

The table is pure text derived from committed artifacts — regenerate
after adding a snapshot:

  python -m benchmarks.run --quick --label prN \\
      --json results/bench/BENCH_prN.json
  python -m benchmarks.history --out results/bench/TRAJECTORY.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
from typing import Dict, List, Optional, Tuple


def _label_key(label: str) -> Tuple[int, float, str]:
    """Sort key: seed < pr1 < pr2 < ... < pr10 < everything else."""
    if label == "seed":
        return (0, 0.0, "")
    m = re.fullmatch(r"pr(\d+)", label)
    if m:
        return (1, float(m.group(1)), "")
    return (2, 0.0, label)


def load_snapshots(bench_dir: str) -> List[dict]:
    """All ``BENCH_*.json`` artifacts under ``bench_dir`` in PR order.
    Unreadable files are skipped with a stderr note (a half-written
    artifact must not take the whole table down)."""
    snaps = []
    for path in glob.glob(os.path.join(bench_dir, "BENCH_*.json")):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError) as e:
            print(f"# skipping {path}: {e}", file=sys.stderr)
            continue
        label = data.get("label") or os.path.basename(path)[6:-5]
        snaps.append({"label": str(label), "path": path,
                      "results": data.get("results", [])})
    snaps.sort(key=lambda s: _label_key(s["label"]))
    return snaps


def trajectory(snaps: List[dict]) -> Tuple[List[str], List[str],
                                           Dict[str, Dict[str, float]]]:
    """``(labels, metric_names, values[metric][label] -> us_per_call)``.
    Metric order is first appearance across the ordered snapshots."""
    labels = [s["label"] for s in snaps]
    metrics: List[str] = []
    values: Dict[str, Dict[str, float]] = {}
    for s in snaps:
        for r in s["results"]:
            name = r.get("name")
            if not name or "us_per_call" not in r:
                continue
            if name not in values:
                metrics.append(name)
                values[name] = {}
            values[name][s["label"]] = float(r["us_per_call"])
    return labels, metrics, values


def _fmt(us: Optional[float]) -> str:
    if us is None:
        return ""
    if us >= 1000.0:
        return f"{us / 1000.0:.2f}ms"
    return f"{us:.1f}us"


def format_trajectory_md(bench_dir: str = "results/bench") -> str:
    """The full markdown document: one table per metric group (the
    ``<group>/`` prefix), one column per committed snapshot, plus a
    last-vs-first drift column for rows present in both."""
    snaps = load_snapshots(bench_dir)
    if not snaps:
        return ("# Benchmark trajectory\n\nNo BENCH_*.json artifacts "
                f"found under `{bench_dir}`.\n")
    labels, metrics, values = trajectory(snaps)
    lines = [
        "# Benchmark trajectory",
        "",
        "`us_per_call` of every benchmark row across the committed",
        f"`BENCH_*.json` snapshots ({', '.join(labels)}).  Blank cells:",
        "the metric did not exist yet.  *drift* compares the newest",
        "snapshot against the oldest one carrying the row (wall-clock —",
        "machine-dependent; the CI gate normalizes, this table does not).",
        "",
        "Regenerate: `python -m benchmarks.history --out "
        "results/bench/TRAJECTORY.md`",
    ]
    groups: List[str] = []
    for name in metrics:
        g = name.split("/", 1)[0]
        if g not in groups:
            groups.append(g)
    for g in groups:
        rows = [m for m in metrics if m.split("/", 1)[0] == g]
        lines += ["", f"## {g}", "",
                  "| metric | " + " | ".join(labels) + " | drift |",
                  "|---" * (len(labels) + 2) + "|"]
        for m in rows:
            vals = values[m]
            cells = [_fmt(vals.get(lb)) for lb in labels]
            present = [vals[lb] for lb in labels if lb in vals]
            drift = ""
            if len(present) >= 2 and present[0] > 0:
                drift = f"{present[-1] / present[0]:.2f}x"
            lines.append("| " + " | ".join([f"`{m}`"] + cells + [drift])
                         + " |")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench-dir", default="results/bench",
                    help="directory holding the BENCH_*.json snapshots")
    ap.add_argument("--out", default="",
                    help="write the markdown here instead of stdout")
    args = ap.parse_args(argv)
    md = format_trajectory_md(args.bench_dir)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)
        print(f"# wrote {args.out}", file=sys.stderr)
    else:
        print(md, end="")
    return 0


if __name__ == "__main__":
    sys.exit(main())
