"""Fig. 13 — active spot / on-demand instances over time, per policy."""
from __future__ import annotations

import csv
import os

from repro.core import ScenarioConfig

from .common import RESULTS_DIR, emit, run_market

POLICIES = ["first-fit", "hlem-vmp", "hlem-vmp-adjusted"]


def run(quick: bool = True):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    cfg = ScenarioConfig(seed=0)
    rows = []
    for pol in POLICIES:
        sim, metrics, wall = run_market(pol, cfg, record_timeline=True)
        path = os.path.join(RESULTS_DIR, f"fig13_{pol}.csv")
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["time", "active_spot", "active_od", "waiting",
                        "hibernated"])
            w.writerows(metrics.timeline)
        peak_spot = max((t[1] for t in metrics.timeline), default=0)
        peak_od = max((t[2] for t in metrics.timeline), default=0)
        rows.append(emit(
            f"fig13/{pol}", wall * 1e6 / max(metrics.allocations, 1),
            f"peak_spot={peak_spot};peak_od={peak_od};csv={path}"))
    return rows
