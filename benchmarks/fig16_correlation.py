"""Fig. 16 / §VII-F — interruption-frequency association analysis
(Theil's U, correlation ratio, Pearson) over the synthetic Spot-Advisor
dataset.  Expected ordering (paper): instance_type > family > category;
day / free_tier ~ 0."""
from __future__ import annotations

import time

from repro.market import association_matrix, generate_advisor_dataset
from repro.market.advisor import KINDS

from .common import emit


def run(quick: bool = True):
    cols = generate_advisor_dataset(600 if quick else 1200, seed=1)
    t0 = time.time()
    am = association_matrix(cols, KINDS)
    wall = time.time() - t0
    row = am["interruption_band"]
    ordered = sorted(((k, v) for k, v in row.items()
                      if k != "interruption_band"), key=lambda kv: -kv[1])
    top3 = ";".join(f"{k}={v:.2f}" for k, v in ordered[:3])
    ok = (row["instance_type"] > row["family"] > row["category"]
          and row["day"] < 0.15 and row["free_tier"] < 0.15)
    return [emit("fig16/associations", wall * 1e6,
                 f"{top3};ordering_matches_paper={ok}")]
