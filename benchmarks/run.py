"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--full] [--only fig14,...]``
prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

MODULES = [
    "fig13_active_instances",   # Fig. 13: active instances over time
    "fig14_interruptions",      # Fig. 14: interruption counts per policy
    "fig15_durations",          # Fig. 15: interruption durations
    "trace_scale",              # §VII-C/D: trace-scale simulation
    "fig16_correlation",        # Fig. 16: advisor association analysis
    "allocation_throughput",    # §VII-D1: scoring throughput (np/jax/pallas)
    "victim_selection",         # beyond-paper: §IX victim selectors
    "cost_analysis",            # beyond-paper: $ cost / waste per policy
    "roofline",                 # §Roofline from dry-run artifacts
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-scale runs (slower)")
    ap.add_argument("--only", default="",
                    help="comma-separated module subset")
    args = ap.parse_args(argv)

    selected = args.only.split(",") if args.only else MODULES
    print("name,us_per_call,derived")
    failures = 0
    for name in selected:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.run(quick=not args.full)
        except Exception:
            failures += 1
            print(f"{name},0,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    return 1 if failures else 0


def main_legacy() -> None:  # kept for the original scaffold entry point
    raise SystemExit(main())


if __name__ == "__main__":
    raise SystemExit(main())
