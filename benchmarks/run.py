"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--full] [--only fig14,...]``
prints ``name,us_per_call,derived`` CSV rows and writes a machine-readable
``BENCH_<label>.json`` artifact (results/bench/ by default) so the perf
trajectory is tracked across PRs — compare against the committed
``BENCH_seed.json`` baseline.
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time
import traceback

from .common import RESULTS_DIR

MODULES = [
    "fig13_active_instances",   # Fig. 13: active instances over time
    "fig14_interruptions",      # Fig. 14: interruption counts per policy
    "fig15_durations",          # Fig. 15: interruption durations
    "trace_scale",              # §VII-C/D: trace-scale simulation
    "fig16_correlation",        # Fig. 16: advisor association analysis
    "allocation_throughput",    # §VII-D1: scoring throughput (np/jax/pallas)
    "market_engine",            # PR 2: wave selection + engine end-to-end
    "price_layer",              # PR 5: fused price ticks + batched billing
    "fleet",                    # PR 6: fleet replenish planner + liveness scan
    "serve",                    # PR 10: autoscale tick + request throughput
    "migration",                # PR 3: migration-planner throughput
    "victim_selection",         # beyond-paper: §IX victim selectors
    "cost_analysis",            # beyond-paper: $ cost / waste per policy
    "roofline",                 # §Roofline from dry-run artifacts
]

DEFAULT_JSON_DIR = RESULTS_DIR


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-scale runs (slower)")
    ap.add_argument("--only", default="",
                    help="comma-separated module subset")
    ap.add_argument("--label", default="",
                    help="artifact label -> BENCH_<label>.json "
                         "(default: quick|full)")
    ap.add_argument("--json-dir", default=DEFAULT_JSON_DIR,
                    help="directory for the JSON artifact")
    args = ap.parse_args(argv)

    label = args.label or ("full" if args.full else "quick")
    selected = args.only.split(",") if args.only else MODULES
    print("name,us_per_call,derived")
    failures = 0
    results = []
    for name in selected:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            rows = mod.run(quick=not args.full)
            if rows:
                results.extend(r for r in rows if isinstance(r, dict))
        except Exception:
            failures += 1
            print(f"{name},0,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)

    os.makedirs(args.json_dir, exist_ok=True)
    path = os.path.join(args.json_dir, f"BENCH_{label}.json")
    with open(path, "w") as f:
        json.dump({
            "label": label,
            "mode": "full" if args.full else "quick",
            "modules": selected,
            "failures": failures,
            "results": results,
        }, f, indent=1)
    print(f"# wrote {path}", flush=True)
    return 1 if failures else 0


def main_legacy() -> None:  # kept for the original scaffold entry point
    raise SystemExit(main())


if __name__ == "__main__":
    raise SystemExit(main())
