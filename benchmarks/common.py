"""Shared benchmark utilities. Every benchmark prints
``name,us_per_call,derived`` CSV rows (one per measured quantity)."""
from __future__ import annotations

import copy
import os
import time
from typing import Callable, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "bench")


def emit(name: str, us_per_call: float, derived: str = "") -> dict:
    """Print one CSV row and return it as a dict (collected by run.py into
    the machine-readable ``BENCH_<label>.json`` artifact)."""
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)
    return {"name": name, "us_per_call": round(us_per_call, 3),
            "derived": derived}


def timeit(fn: Callable, n: int = 5, warmup: int = 1) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def run_market(policy_name: str, scenario_cfg=None, until: float = 2200.0,
               selector: str = "list_order", alpha: float = -0.5,
               record_timeline: bool = False):
    """One §VII-E run; returns (sim, metrics, wall_s)."""
    from repro.core import (
        MarketSimulator, ScenarioConfig, SimConfig, make_policy,
        synthetic_scenario,
    )
    cfg = scenario_cfg or ScenarioConfig(seed=0)
    hosts, vms = synthetic_scenario(cfg)
    kwargs = {"alpha": alpha} if policy_name == "hlem-vmp-adjusted" else {}
    sim = MarketSimulator(
        policy=make_policy(policy_name, **kwargs),
        config=SimConfig(record_timeline=record_timeline,
                         interruption_selector=selector))
    for cap in hosts:
        sim.add_host(cap)
    for v in vms:
        sim.submit(copy.deepcopy(v))
    t0 = time.time()
    metrics = sim.run(until=until)
    return sim, metrics, time.time() - t0
