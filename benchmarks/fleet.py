"""Fleet-manager hot-path benchmarks (PR 6).

Two row pairs, each cross-checked against its per-pool / per-row Python
oracle before timing:

* ``market/fleet_replenish`` — the vectorized residual-capacity
  apportionment planner (:func:`repro.market.fleet.plan_replenish`) over a
  batch of shortfall snapshots, vs the per-pool reference walk
  (``market/fleet_replenish_pyref``, :func:`plan_replenish_ref`).  Every
  snapshot's launch counts are asserted bit-identical first.
* ``market/fleet_capacity`` — the registry liveness scan
  (:func:`fleet_pool_capacity`: one sorted-membership test + two bincounts
  over a ~20k-row synthetic RUNNING-spot registry), vs the per-row walk
  (``market/fleet_capacity_pyref``).
"""
from __future__ import annotations

import numpy as np

from repro.market import (
    fleet_pool_capacity,
    fleet_pool_capacity_ref,
    plan_replenish,
    plan_replenish_ref,
)

from .common import emit, timeit


def _snapshots(n_snaps: int, n_pools: int, seed: int = 0):
    """Synthetic per-tick planning inputs (shortfall, holdings, market)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_snaps):
        need = int(rng.integers(1, 64))
        cur = rng.integers(0, 32, size=n_pools)
        weights = np.where(rng.random(n_pools) < 0.15, 0.0,
                           rng.uniform(0.1, 3.0, n_pools))
        if not weights.any():
            weights[0] = 1.0
        prices = np.round(rng.uniform(0.05, 1.2, n_pools), 2)
        bids = np.full(n_pools, 0.6)
        free = np.round(rng.uniform(0.0, 120.0, n_pools), 1)
        out.append((need, cur, weights, prices, bids, free))
    return out


def bench_replenish(n_snaps: int, n_pools: int, strategy: str = "diversified"):
    snaps = _snapshots(n_snaps, n_pools)
    for s in snaps:
        vec = plan_replenish(*s, 2.0, strategy)
        ref = plan_replenish_ref(*s, 2.0, strategy)
        assert np.array_equal(vec, ref), \
            "vectorized replenish diverged from the per-pool reference"

    def vec_all():
        for s in snaps:
            plan_replenish(*s, 2.0, strategy)

    def ref_all():
        for s in snaps:
            plan_replenish_ref(*s, 2.0, strategy)

    t_vec = timeit(vec_all, n=9) / n_snaps
    t_ref = timeit(ref_all, n=3) / n_snaps
    return [
        emit(f"market/fleet_replenish_p{n_pools}", t_vec,
             f"snaps={n_snaps};strategy={strategy};"
             f"speedup_vs_pyref={t_ref / t_vec:.1f}x"),
        emit(f"market/fleet_replenish_pyref_p{n_pools}", t_ref, ""),
    ]


def bench_capacity(n_rows: int, n_pools: int, n_fleet: int):
    rng = np.random.default_rng(1)
    vids = np.sort(rng.permutation(n_rows * 4)[:n_rows]).astype(np.int64)
    registry = {
        "vid": vids,
        "pool": rng.integers(0, n_pools, size=n_rows),
        "cpu": rng.uniform(1.0, 4.0, size=n_rows),
    }
    fleet_vids = np.sort(rng.choice(n_rows * 4, size=n_fleet,
                                    replace=False)).astype(np.int64)

    units, cpu = fleet_pool_capacity(registry, fleet_vids, n_pools)
    r_units, r_cpu = fleet_pool_capacity_ref(registry, fleet_vids, n_pools)
    assert np.array_equal(units, r_units) and np.array_equal(cpu, r_cpu), \
        "vectorized capacity scan diverged from the per-row reference"

    t_vec = timeit(lambda: fleet_pool_capacity(registry, fleet_vids,
                                               n_pools), n=9)
    t_ref = timeit(lambda: fleet_pool_capacity_ref(registry, fleet_vids,
                                                   n_pools), n=3)
    return [
        emit(f"market/fleet_capacity_r{n_rows}", t_vec,
             f"pools={n_pools};fleet={n_fleet};"
             f"speedup_vs_pyref={t_ref / t_vec:.1f}x"),
        emit(f"market/fleet_capacity_pyref_r{n_rows}", t_ref, ""),
    ]


def run(quick: bool = True):
    rows = []
    n_snaps = 200 if quick else 1_000
    for strategy in ("diversified",) if quick else ("diversified",
                                                    "lowest-price"):
        rows.extend(bench_replenish(n_snaps, n_pools=64, strategy=strategy))
    rows.extend(bench_capacity(n_rows=20_000 if quick else 80_000,
                               n_pools=64, n_fleet=2_000))
    return rows
