"""§Roofline — report the three roofline terms per (arch x shape) from the
dry-run artifacts (results/dryrun/*.json; run ``python -m
repro.launch.dryrun`` first)."""
from __future__ import annotations

import glob
import json
import os

from .common import emit

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def run(quick: bool = True):
    rows = []
    files = sorted(glob.glob(os.path.join(DRYRUN, "*.json")))
    if not files:
        return [emit("roofline/missing", 0.0,
                     "run: PYTHONPATH=src python -m repro.launch.dryrun")]
    for path in files:
        with open(path) as f:
            r = json.load(f)
        if r.get("status") != "ok" or r.get("mesh") != "pod16x16":
            continue
        rf = r["roofline"]
        frac = rf["compute_s"] / max(rf["bound_s"], 1e-30)
        rows.append(emit(
            f"roofline/{r['arch']}/{r['shape']}",
            rf["bound_s"] * 1e6,
            f"dominant={rf['dominant']};"
            f"compute_ms={rf['compute_s']*1e3:.1f};"
            f"memory_ms={rf['memory_s']*1e3:.1f};"
            f"collective_ms={rf['collective_s']*1e3:.1f};"
            f"roofline_fraction={frac:.3f};"
            f"useful_flops_ratio={r.get('useful_flops_ratio') or 0:.2f}"))
    return rows
