"""Beyond-paper: cost accounting per allocation policy (the paper frames its
contribution as insight into cost-performance trade-offs, §III, but does not
quantify cost; we price execution histories with an AWS-like rate model).

Key question: do interruption-aware policies also reduce WASTED spend
(terminated spot VMs pay for partial work that is thrown away)?"""
from __future__ import annotations

from repro.core import InterruptionBehavior, ScenarioConfig
from repro.market import cost_stats

from .common import emit, run_market

POLICIES = ["first-fit", "hlem-vmp", "hlem-vmp-adjusted"]


def run(quick: bool = True):
    rows = []
    # TERMINATE behavior makes waste visible (hibernation never wastes spend)
    cfg = ScenarioConfig(seed=0,
                         spot_behavior=InterruptionBehavior.TERMINATE)
    for pol in POLICIES:
        sim, metrics, wall = run_market(pol, cfg)
        s = cost_stats(sim.all_vms())
        ints = metrics.spot_stats(sim.vms)["interruptions"]
        rows.append(emit(
            f"cost/{pol}", wall * 1e6 / max(metrics.allocations, 1),
            f"cost=${s['cost']:.2f};savings_pct={s['savings_pct']:.1f};"
            f"wasted=${s['wasted_cost']:.3f};interruptions={ints}"))
    return rows
