"""Array-native market-state benchmarks (PR 5 tentpole).

Two row pairs, each measured against the retained legacy path and
cross-checked for identical results:

* ``market/price_tick_batch_p<N>`` — one fused PRICE_TICK (family step over
  the packed MarketState + history-segment close) at N pools, vs the
  per-pool scalar oracle walk (``market/price_tick_scalar_p<N>``, the pr4
  tick structure — the row the CI gate normalizes against).  Both engines
  consume identical shock streams; the resulting price histories are
  asserted bit-identical.
* ``market/realized_billing_b<B>`` — batched
  :meth:`MarketEngine.price_integrals` billing B random bid-capped spans in
  one call, vs the per-span historical ``bisect`` walk
  (``market/realized_billing_pyref_b<B>``,
  :func:`repro.market.engine.price_integral_ref`), values cross-checked.
"""
from __future__ import annotations

import numpy as np

from repro.market import MarketConfig, MarketEngine, PoolConfig
from repro.market.engine import price_integral_ref

from .common import emit, timeit


class _StubHostPool:
    """Fixed utilization signal: the rows isolate the price-layer cost from
    host accounting (which trace_scale / engine_e2e already cover)."""

    def __init__(self, util: np.ndarray):
        self._util = util

    def pool_cpu_utilization(self) -> np.ndarray:
        return self._util


def _make_engine(n_pools: int, vectorized: bool, seed: int = 0):
    rng = np.random.default_rng(seed)
    pools = [PoolConfig(f"p{i}", process="auction", seed=seed + i,
                        process_kwargs={
                            "shock_sigma": float(rng.uniform(0.2, 0.5)),
                            "shock_rho": 0.75})
             for i in range(n_pools)]
    return MarketEngine(MarketConfig(pools, tick_interval=60.0, seed=seed,
                                     vectorized=vectorized))


def _run_ticks(eng, stub, n_ticks: int, t0: float = 0.0) -> float:
    t = t0
    for _ in range(n_ticks):
        eng.tick(stub, t)
        t += eng.tick_interval
    return t


def bench_price_tick(n_pools: int, n_ticks: int):
    rng = np.random.default_rng(1)
    util = rng.uniform(0.2, 0.9, n_pools)
    stub = _StubHostPool(util)

    # identical shocks + kernels: the two paths must agree bit for bit
    vec, sca = _make_engine(n_pools, True), _make_engine(n_pools, False)
    _run_ticks(vec, stub, 32)
    _run_ticks(sca, stub, 32)
    assert np.array_equal(vec.price_history(), sca.price_history()), \
        "vectorized tick diverged from the scalar oracle"

    state = {"t": 3600.0 * 64}

    def tick_n(eng):
        state["t"] = _run_ticks(eng, stub, n_ticks, state["t"])

    t_vec = timeit(lambda: tick_n(vec), n=9) / n_ticks
    t_sca = timeit(lambda: tick_n(sca), n=5) / n_ticks
    rows = [
        emit(f"market/price_tick_batch_p{n_pools}", t_vec,
             f"ticks={n_ticks};speedup_vs_scalar={t_sca / t_vec:.1f}x"),
        emit(f"market/price_tick_scalar_p{n_pools}", t_sca,
             f"ticks={n_ticks}"),
    ]
    return rows


def bench_realized_billing(n_pools: int, n_queries: int, n_ticks: int = 240):
    rng = np.random.default_rng(2)
    eng = _make_engine(n_pools, True, seed=3)
    stub = _StubHostPool(rng.uniform(0.2, 0.9, n_pools))
    _run_ticks(eng, stub, n_ticks)
    t_end = n_ticks * eng.tick_interval
    pids = rng.integers(0, n_pools, n_queries)
    t0s = rng.uniform(0.0, t_end, n_queries)
    t1s = t0s + rng.uniform(30.0, t_end / 3, n_queries)
    caps = rng.uniform(0.2, 1.0, n_queries)

    batched = eng.price_integrals(pids, t0s, t1s, caps)
    sample = rng.integers(0, n_queries, 200)
    for k in sample:
        ref = price_integral_ref(eng, int(pids[k]), float(t0s[k]),
                                 float(t1s[k]), float(caps[k]))
        assert abs(batched[k] - ref) <= 1e-9 * max(1.0, abs(ref)), \
            "batched billing diverged from the bisect reference"

    t_bat = timeit(lambda: eng.price_integrals(pids, t0s, t1s, caps), n=9)

    def pyref():
        return [price_integral_ref(eng, int(pids[k]), float(t0s[k]),
                                   float(t1s[k]), float(caps[k]))
                for k in range(n_queries)]

    t_ref = timeit(pyref, n=3)
    rows = [
        emit(f"market/realized_billing_b{n_queries}", t_bat,
             f"ticks={n_ticks};pools={n_pools};"
             f"speedup_vs_pyref={t_ref / t_bat:.1f}x"),
        emit(f"market/realized_billing_pyref_b{n_queries}", t_ref, ""),
    ]
    return rows


def run(quick: bool = True):
    rows = []
    for n_pools in ([64] if quick else [64, 256]):
        rows.extend(bench_price_tick(n_pools, n_ticks=64))
    rows.extend(bench_realized_billing(
        n_pools=64, n_queries=5_000 if quick else 20_000))
    return rows
