"""Dynamic market engine benchmarks (PR 2 tentpole).

Two rows per registry size:

* ``market/wave_select_m<N>`` — interruption-wave victim selection over a
  dense registry of N running spot VMs: one masked comparison
  (:meth:`HostPool.market_victims`) vs the equivalent per-VM Python walk
  (``market/wave_select_pyloop_m<N>``, the row the CI gate normalizes
  against), cross-checked for identical victim sets.
* ``market/engine_e2e_volatile`` — end-to-end market-scenario run with the
  engine under the volatile regime (price ticks + waves + price-gated
  admission), us per allocation.
* ``market/engine_e2e_migration`` — the same run with the gradient-aware
  migration planner attached (PR 3): planner overhead rides on the same
  metric.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import HostPool, VmState, make_spot, resources

from .common import emit, timeit

_EPS = 1e-9
N_POOLS = 4


def _build_registry(m: int, seed: int = 0):
    pool = HostPool()
    pool.enable_market(N_POOLS)
    rng = np.random.default_rng(seed)
    n_hosts = max(m // 50, N_POOLS)
    huge = resources(1e9, 1e12, 1e9, 1e12)
    for h in range(n_hosts):
        pool.add_host(huge, pool=h % N_POOLS)
    for i in range(m):
        vm = make_spot(i, resources(1, 1024, 10, 1000), 1e6,
                       bid=float(rng.uniform(0.15, 1.0)),
                       min_running_time=float(rng.choice([0.0, 50.0])))
        hid = int(rng.integers(n_hosts))
        pool.place(vm, hid, now=0.0)
        vm.state = VmState.RUNNING
        vm.run_start = 0.0
    return pool


def _reference_victims(pool: HostPool, prices: np.ndarray, now: float):
    out = []
    for h in range(pool.n):
        price = prices[pool.pool_of[h]]
        for v in pool.spot_vms_on(h):
            if v.interruptible(now) and v.bid < price - _EPS:
                out.append(v.id)
    return out


def run(quick: bool = True):
    rows = []
    sizes = [2_000, 20_000] if quick else [2_000, 20_000, 200_000]
    rng = np.random.default_rng(1)
    for m in sizes:
        pool = _build_registry(m)
        prices = rng.uniform(0.2, 0.9, N_POOLS)
        now = 30.0  # half the min_running_time population is still protected
        vec, _ = pool.market_victims(prices, now)
        ref = _reference_victims(pool, prices, now)
        assert sorted(vec.tolist()) == sorted(ref), "victim sets diverge"
        t_vec = timeit(lambda: pool.market_victims(prices, now), n=9)
        t_ref = timeit(lambda: _reference_victims(pool, prices, now), n=3)
        rows.append(emit(
            f"market/wave_select_m{m}", t_vec,
            f"victims={vec.size};speedup_vs_pyloop={t_ref / t_vec:.1f}x"))
        rows.append(emit(f"market/wave_select_pyloop_m{m}", t_ref,
                         f"victims={len(ref)}"))

    # end-to-end rows go through the declarative scenario API: one RunSpec
    # per row, fresh engine/planner materialized by api.run_one
    from repro.api import (
        BidSpec, MigrationSpec, PolicySpec, RunSpec, ScenarioSpec, run_one,
    )
    until = 3600.0 if quick else 14400.0
    scenario = ScenarioSpec(workload="market", regime="volatile",
                            bid=BidSpec("randomized", {"lo": 0.45}))
    policy = PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5})
    t0 = time.time()
    r = run_one(RunSpec(scenario=scenario, policy=policy), seed=0,
                until=until)
    wall = time.time() - t0
    rows.append(emit(
        "market/engine_e2e_volatile",
        wall * 1e6 / max(r["allocations"], 1),
        f"allocations={r['allocations']};waves={r['waves']};"
        f"price_interruptions={r['price_interruptions']};"
        f"spot_cost={r['realized_spot_cost']}"))
    t0 = time.time()
    r = run_one(RunSpec(scenario=scenario, policy=policy,
                        migration=MigrationSpec("gradient-aware")),
                seed=0, until=until)
    wall = time.time() - t0
    rows.append(emit(
        "market/engine_e2e_migration",
        wall * 1e6 / max(r["allocations"], 1),
        f"allocations={r['allocations']};migrations={r['migrations']};"
        f"price_interruptions={r['price_interruptions']};"
        f"downtime_s={r['migration_downtime_s']}"))
    return rows
