"""§VII-D1 — allocation scoring throughput (the paper's 1.5-day-per-
simulated-day bottleneck): numpy oracle vs fused pick vs jitted JAX vs Pallas
kernel (interpret), plus the batched B×n scoring paths, swept over fleet
sizes."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import hlem_scores_np
from repro.core.hlem import (
    BATCH_NP_N_CUTOVER,
    hlem_pick_np,
    hlem_scores_batch_np,
    hlem_scores_jax,
)

from .common import emit, timeit


def run(quick: bool = True):
    rows = []
    sizes = [100, 1000] if quick else [100, 1000, 12600]
    rng = np.random.default_rng(0)
    for n in sizes:
        free = rng.uniform(0, 100, (n, 4)).astype(np.float32)
        mask = rng.random(n) < 0.7
        spot = rng.uniform(0, 1, (n, 4)).astype(np.float32)
        t_np = timeit(lambda: hlem_scores_np(free, mask, spot, -0.5), n=9)
        t_pick = timeit(lambda: hlem_pick_np(free, mask, spot, -0.5), n=9)
        fj = jnp.asarray(free); mj = jnp.asarray(mask); sj = jnp.asarray(spot)
        a = jnp.float32(-0.5)
        t_jax = timeit(
            lambda: hlem_scores_jax(fj, mj, sj, a).block_until_ready(), n=9)
        rows.append(emit(f"alloc/numpy_n{n}", t_np, ""))
        rows.append(emit(f"alloc/pick_np_n{n}", t_pick,
                         f"speedup_vs_numpy={t_np / t_pick:.1f}x"))
        rows.append(emit(f"alloc/jax_n{n}", t_jax,
                         f"speedup_vs_numpy={t_np / t_jax:.1f}x"))
        # batched resubmission-queue scoring: B pending VMs in one pass
        b = 8 if quick else 32
        masks = rng.random((b, n)) < 0.7
        alphas = np.where(rng.random(b) < 0.5, -0.5, 0.0)
        t_loop = timeit(lambda: [hlem_scores_np(free, masks[i], spot,
                                                alphas[i])
                                 for i in range(b)], n=5)
        t_batch = timeit(lambda: hlem_scores_batch_np(free, masks, spot,
                                                      alphas), n=5)
        derived = f"speedup_vs_loop={t_loop / t_batch:.1f}x"
        if n > BATCH_NP_N_CUTOVER:
            # force the (B, n, D) broadcast core to expose the large-n
            # routing win (the default routes such fleets through the
            # compressed per-row oracle; below the cutover they coincide)
            t_bcast = timeit(lambda: hlem_scores_batch_np(
                free, masks, spot, alphas, n_cutover=10 ** 9), n=5)
            derived += f";speedup_vs_broadcast={t_bcast / t_batch:.1f}x"
        rows.append(emit(f"alloc/batch_np_B{b}_n{n}", t_batch, derived))
        if n <= 1000:  # interpret mode is slow; correctness-scale only
            from repro.kernels.hlem_score import (
                hlem_score_pallas,
                hlem_score_pallas_batch,
            )
            t_pl = timeit(lambda: hlem_score_pallas(
                fj, mj, sj, a, interpret=True).block_until_ready(), n=3)
            rows.append(emit(f"alloc/pallas_interp_n{n}", t_pl,
                             "interpret-mode (CPU); TPU target"))
            bj = jnp.asarray(masks[:4])
            aj = jnp.asarray(alphas[:4], jnp.float32)
            t_plb = timeit(lambda: hlem_score_pallas_batch(
                fj, bj, sj, aj, interpret=True).block_until_ready(), n=3)
            rows.append(emit(f"alloc/pallas_batch_interp_B4_n{n}", t_plb,
                             "interpret-mode (CPU); TPU target"))
    return rows
