"""§VII-D1 — allocation scoring throughput (the paper\'s 1.5-day-per-
simulated-day bottleneck): numpy oracle vs jitted JAX vs Pallas kernel
(interpret), swept over fleet sizes."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import hlem_scores_np
from repro.core.hlem import hlem_scores_jax
from repro.kernels.hlem_score import hlem_score_pallas

from .common import emit, timeit


def run(quick: bool = True):
    rows = []
    sizes = [100, 1000, 12600] if not quick else [100, 1000, 12600]
    rng = np.random.default_rng(0)
    for n in sizes:
        free = rng.uniform(0, 100, (n, 4)).astype(np.float32)
        mask = rng.random(n) < 0.7
        spot = rng.uniform(0, 1, (n, 4)).astype(np.float32)
        t_np = timeit(lambda: hlem_scores_np(free, mask, spot, -0.5), n=9)
        fj = jnp.asarray(free); mj = jnp.asarray(mask); sj = jnp.asarray(spot)
        a = jnp.float32(-0.5)
        t_jax = timeit(
            lambda: hlem_scores_jax(fj, mj, sj, a).block_until_ready(), n=9)
        rows.append(emit(f"alloc/numpy_n{n}", t_np, ""))
        rows.append(emit(f"alloc/jax_n{n}", t_jax,
                         f"speedup_vs_numpy={t_np / t_jax:.1f}x"))
        if n <= 1000:  # interpret mode is slow; correctness-scale only
            t_pl = timeit(lambda: hlem_score_pallas(
                fj, mj, sj, a, interpret=True).block_until_ready(), n=3)
            rows.append(emit(f"alloc/pallas_interp_n{n}", t_pl,
                             "interpret-mode (CPU); TPU target"))
    return rows
