"""Fig. 14 — total spot-instance interruptions per allocation policy.

Expected qualitative result (paper §VII-E3): First-Fit most interruptions,
HLEM-VMP fewer, adjusted HLEM-VMP fewest (paper: 286 / 230 / 205)."""
from __future__ import annotations

from repro.core import ScenarioConfig

from .common import emit, run_market

POLICIES = ["first-fit", "hlem-vmp", "hlem-vmp-adjusted"]


def run(quick: bool = True):
    rows = []
    counts = {}
    for pol in POLICIES:
        sim, metrics, wall = run_market(pol, ScenarioConfig(seed=0))
        s = metrics.spot_stats(sim.vms)
        counts[pol] = s["interruptions"]
        rows.append(emit(
            f"fig14/{pol}", wall * 1e6 / max(metrics.allocations, 1),
            f"interruptions={s['interruptions']};"
            f"max_per_vm={s['max_interruptions_per_vm']};"
            f"spot_finished={s['spot_finished']}"))
    ordered = (counts["first-fit"] >= counts["hlem-vmp"] >=
               counts["hlem-vmp-adjusted"])
    rows.append(emit("fig14/ordering_matches_paper", 0.0,
                     f"ff>=hlem>=adjusted={ordered}"))
    return rows
