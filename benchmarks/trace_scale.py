"""§VII-C/D — Google-Cluster-Trace-style large-scale simulation (scaled).

The paper runs 12.6k machines / 2.38M VMs / 200k spot for 2 days; offline we
run a seeded synthetic trace with the same structure at configurable scale
and report the paper's §VII-D2 statistics (completion/interruption mix,
average and max interruption durations)."""
from __future__ import annotations

from repro.core import SimConfig, make_policy
from repro.market import TraceConfig, generate_trace, simulate_trace

from .common import emit


def run(quick: bool = True):
    cfg = TraceConfig(seed=0,
                      n_machines=60 if quick else 400,
                      sim_days=0.08 if quick else 0.5,
                      n_spot=300 if quick else 2000,
                      load_per_machine=30.0,
                      spot_durations_h=(1.0, 2.0) if quick else (20.0, 40.0))
    tr = generate_trace(cfg)
    import time
    t0 = time.time()
    sim, metrics = simulate_trace(
        tr, policy=make_policy("hlem-vmp-adjusted"), cfg=cfg)
    wall = time.time() - t0
    s = metrics.spot_stats(sim.vms)
    uninterrupted_pct = 100.0 * s["spot_finished_uninterrupted"] / max(
        cfg.n_spot, 1)
    rows = [emit(
        "trace/hlem-vmp-adjusted",
        wall * 1e6 / max(metrics.allocations, 1),
        f"machines={cfg.n_machines};vms={len(sim.vms)};"
        f"interruptions={s['interruptions']};"
        f"uninterrupted_pct={uninterrupted_pct:.1f};"
        f"avg_interruption_s={s['avg_interruption_time']:.0f};"
        f"max_interruption_s={s['max_interruption_time']:.0f};"
        f"redeployed={s['spot_finished_after_interruption']}")]
    return rows
