"""§VII-C/D — Google-Cluster-Trace-style large-scale simulation (scaled).

The paper runs 12.6k machines / 2.38M VMs / 200k spot for 2 days; offline we
run a seeded synthetic trace with the same structure at configurable scale
and report the paper's §VII-D2 statistics (completion/interruption mix,
average and max interruption durations).

The headline row (``trace/hlem-vmp-adjusted``) is the cross-PR perf metric:
us-per-allocation, best of ``REPS`` back-to-back runs (the shared CI/dev
hosts are noisy; best-of-N is the stable comparison against the committed
``BENCH_seed.json``).  A ``trace/per_vm_reference`` row runs the legacy
one-VM-at-a-time resubmission path for an engine-level A/B at identical
decisions."""
from __future__ import annotations

import time

from repro.core import SimConfig, make_policy
from repro.market import TraceConfig, generate_trace, simulate_trace
from repro.obs import EventLog, Tracer

from .common import emit

REPS = 3


def _one(tr, cfg, flush_mode: str, traced: bool = False,
         events: bool = False):
    best, sim, metrics = float("inf"), None, None
    for _ in range(REPS):
        obs = (Tracer(keep_records=False, profile=True) if traced else None)
        evl = EventLog() if events else None
        t0 = time.time()
        sim, metrics = simulate_trace(
            tr, policy=make_policy("hlem-vmp-adjusted"), cfg=cfg,
            sim_config=SimConfig(record_timeline=False,
                                 flush_mode=flush_mode), obs=obs,
            events=evl)
        best = min(best, time.time() - t0)
    return best, sim, metrics


def run(quick: bool = True):
    cfg = TraceConfig(seed=0,
                      n_machines=60 if quick else 400,
                      sim_days=0.08 if quick else 0.5,
                      n_spot=300 if quick else 2000,
                      load_per_machine=30.0,
                      spot_durations_h=(1.0, 2.0) if quick else (20.0, 40.0))
    tr = generate_trace(cfg)
    wall, sim, metrics = _one(tr, cfg, "batched")
    s = metrics.spot_stats(sim.vms)
    uninterrupted_pct = 100.0 * s["spot_finished_uninterrupted"] / max(
        cfg.n_spot, 1)
    rows = [emit(
        "trace/hlem-vmp-adjusted",
        wall * 1e6 / max(metrics.allocations, 1),
        f"machines={cfg.n_machines};vms={len(sim.vms)};"
        f"allocations={metrics.allocations};"
        f"interruptions={s['interruptions']};"
        f"uninterrupted_pct={uninterrupted_pct:.1f};"
        f"avg_interruption_s={s['avg_interruption_time']:.0f};"
        f"max_interruption_s={s['max_interruption_time']:.0f};"
        f"redeployed={s['spot_finished_after_interruption']}")]
    wall_ref, sim_ref, metrics_ref = _one(tr, cfg, "per_vm")
    s_ref = metrics_ref.spot_stats(sim_ref.vms)
    match = (s_ref == s and metrics_ref.allocations == metrics.allocations)
    rows.append(emit(
        "trace/per_vm_reference",
        wall_ref * 1e6 / max(metrics_ref.allocations, 1),
        f"batched_speedup={wall_ref / max(wall, 1e-9):.2f}x;"
        f"decisions_match={match}"))
    # PR 7: same workload with a profile-mode tracer attached
    # (keep_records=False, so memory stays bounded at trace scale).  CI
    # gates this row normalized by the same-run untraced headline
    # (--reference-metric trace/hlem-vmp-adjusted), making the check
    # machine-independent: it compares tracing *overhead*, not host speed.
    wall_obs, sim_obs, metrics_obs = _one(tr, cfg, "batched", traced=True)
    s_obs = metrics_obs.spot_stats(sim_obs.vms)
    rows.append(emit(
        "obs/tracing_overhead",
        wall_obs * 1e6 / max(metrics_obs.allocations, 1),
        f"overhead={wall_obs / max(wall, 1e-9):.3f}x;"
        f"metrics_match={s_obs == s and metrics_obs.allocations == metrics.allocations}"))
    # PR 8: same workload with the event flight recorder attached.  Same
    # normalization scheme as obs/tracing_overhead: CI gates this row
    # against the same-run untraced headline, so the check measures
    # recording overhead, not host speed.
    wall_ev, sim_ev, metrics_ev = _one(tr, cfg, "batched", events=True)
    s_ev = metrics_ev.spot_stats(sim_ev.vms)
    rows.append(emit(
        "obs/eventlog_overhead",
        wall_ev * 1e6 / max(metrics_ev.allocations, 1),
        f"overhead={wall_ev / max(wall, 1e-9):.3f}x;"
        f"events={len(sim_ev.events)};"
        f"metrics_match={s_ev == s and metrics_ev.allocations == metrics.allocations}"))
    return rows
