"""Migration-planner throughput (PR 3 tentpole).

Rows per registry size:

* ``migration/plan_m<N>``       — one full planning pass (dense masked
  screen over a registry of N running spot VMs + impact-aware commit loop)
  via :meth:`MigrationPlanner.plan`.
* ``migration/plan_pyref_m<N>`` — the decision-identical per-VM Python
  oracle (:func:`plan_reference`), cross-checked for identical plans.  This
  is the row the CI gate normalizes against: the planner must stay a dense
  vectorized computation, not a Python walk over the registry.
"""
from __future__ import annotations

import numpy as np

from repro.api import ScenarioSpec, build_engine
from repro.core import HostPool, VmState, make_spot, resources
from repro.market import MigrationConfig, MigrationPlanner, plan_reference

from .common import emit, timeit

N_POOLS = 4


def _build(m: int, seed: int = 0):
    """Registry of ``m`` RUNNING spot VMs over an N-pool fleet with live
    utilization, plus an engine with a few ticks of price history (the
    gradient window's input)."""
    pool = HostPool()
    pool.enable_market(N_POOLS)
    rng = np.random.default_rng(seed)
    n_hosts = max(m // 50, N_POOLS)
    vms_per_host = m / n_hosts
    for h in range(n_hosts):
        # pool utilizations spread ~0.55..0.85 so clearing prices differ and
        # a realistic slice of the registry is at risk / has a refuge
        util_target = 0.55 + 0.10 * (h % N_POOLS)
        cap = resources(vms_per_host / util_target, 1e12, 1e9, 1e12)
        pool.add_host(cap, pool=h % N_POOLS)
    for i in range(m):
        vm = make_spot(i, resources(1, 1024, 10, 1000), 1e6,
                       bid=float(rng.uniform(0.15, 1.0)),
                       min_running_time=float(rng.choice([0.0, 50.0])))
        pool.place(vm, i % n_hosts, now=0.0)  # even spread; hosts never overfill
        vm.state = VmState.RUNNING
        vm.run_start = 0.0
    # engine materialized from a scenario spec (flat per-pool volatility —
    # the registry-shaped world the planner benchmarks against)
    eng = build_engine(ScenarioSpec(workload="market", regime="volatile",
                                    n_pools=N_POOLS, tick_interval=60.0,
                                    from_advisor=False), seed)
    for k in range(6):
        prices = eng.tick(pool, 60.0 * k)
        pool.set_pool_prices(prices)
    return pool, eng


def run(quick: bool = True):
    rows = []
    sizes = [2_000, 20_000] if quick else [2_000, 20_000, 200_000]
    inflight = np.zeros(N_POOLS, dtype=np.int64)
    now = 360.0
    for m in sizes:
        pool, eng = _build(m)
        planner = MigrationPlanner(MigrationConfig(
            policy="risk-budgeted", min_remaining=10.0, cooldown=0.0))
        vec = planner.plan(pool, eng, now, inflight)
        ref = plan_reference(planner, pool, eng, now, inflight)
        assert [(p.vm_id, p.dst_pool) for p in vec] == \
               [(p.vm_id, p.dst_pool) for p in ref], "plans diverge"
        assert all(abs(a.predicted_saving - b.predicted_saving) < 1e-9
                   for a, b in zip(vec, ref))
        t_vec = timeit(lambda: planner.plan(pool, eng, now, inflight), n=9)
        t_ref = timeit(
            lambda: plan_reference(planner, pool, eng, now, inflight), n=3)
        rows.append(emit(
            f"migration/plan_m{m}", t_vec,
            f"plans={len(vec)};speedup_vs_pyref={t_ref / t_vec:.1f}x"))
        rows.append(emit(f"migration/plan_pyref_m{m}", t_ref,
                         f"plans={len(ref)}"))
    return rows
