"""CI perf gate: fail if a benchmark row regressed vs a committed baseline.

  python -m benchmarks.check_regression results/bench/BENCH_ci.json \\
      --baseline results/bench/BENCH_pr1.json \\
      --metric trace/hlem-vmp-adjusted --max-ratio 2.0

Compares ``us_per_call`` of ``--metric`` between the two ``BENCH_*.json``
artifacts and exits 1 when ``current > max_ratio * baseline``.  The 2x
default absorbs shared-runner noise (the repo's benchmarks are best-of-N,
but CI hosts still swing); genuine hot-path regressions are well past it.

``--reference-metric`` makes the gate machine-independent: both sides are
divided by a same-artifact reference row first (CI uses
``trace/per_vm_reference`` — the legacy flush path measured in the same
run), so a CI runner that is uniformly slower than the machine that produced
the committed baseline does not trip the gate.
"""
from __future__ import annotations

import argparse
import json
import sys


def _row(path: str, name: str) -> float:
    with open(path) as f:
        data = json.load(f)
    for r in data.get("results", []):
        if r.get("name") == name:
            return float(r["us_per_call"])
    raise SystemExit(f"error: no row named {name!r} in {path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="freshly produced BENCH_<label>.json")
    ap.add_argument("--baseline", default="results/bench/BENCH_pr1.json")
    ap.add_argument("--metric", default="trace/hlem-vmp-adjusted")
    ap.add_argument("--max-ratio", type=float, default=2.0)
    ap.add_argument("--reference-metric", default=None,
                    help="normalize both sides by this same-artifact row "
                         "(machine-independent comparison)")
    args = ap.parse_args(argv)

    cur = _row(args.current, args.metric)
    base = _row(args.baseline, args.metric)
    unit = "us"
    if args.reference_metric:
        cur /= max(_row(args.current, args.reference_metric), 1e-9)
        base /= max(_row(args.baseline, args.reference_metric), 1e-9)
        unit = f"x {args.reference_metric}"
    ratio = cur / max(base, 1e-9)
    status = "OK" if ratio <= args.max_ratio else "REGRESSION"
    print(f"{args.metric}: current={cur:.3f}{unit} baseline={base:.3f}{unit} "
          f"ratio={ratio:.2f}x (max {args.max_ratio:.1f}x) -> {status}")
    return 0 if ratio <= args.max_ratio else 1


if __name__ == "__main__":
    sys.exit(main())
