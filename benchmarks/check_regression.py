"""CI perf gate: fail if a benchmark row regressed vs a committed baseline.

  python -m benchmarks.check_regression results/bench/BENCH_ci.json \\
      --baseline results/bench/BENCH_pr3.json \\
      --metric trace/hlem-vmp-adjusted --max-ratio 2.0

Compares ``us_per_call`` of each ``--metric`` between the two
``BENCH_*.json`` artifacts and exits 1 when ``current > max_ratio *
baseline`` for any of them.  The 2x default absorbs shared-runner noise
(the repo's benchmarks are best-of-N, but CI hosts still swing); genuine
hot-path regressions are well past it.

``--reference-metric`` makes the gate machine-independent: both sides are
divided by a same-artifact reference row first, so a CI runner that is
uniformly slower than the machine that produced the committed baseline does
not trip the gate.

``--metric`` / ``--reference-metric`` accept comma-separated lists and are
paired positionally (CI gates ``trace/hlem-vmp-adjusted`` against the
same-run legacy flush and ``market/wave_select_m20000`` against the
same-run per-VM Python walk in one invocation).  A reference entry of ``-``
means "no normalization for this metric".
"""
from __future__ import annotations

import argparse
import json
import sys


def _row(path: str, name: str) -> float:
    with open(path) as f:
        data = json.load(f)
    for r in data.get("results", []):
        if r.get("name") == name:
            return float(r["us_per_call"])
    raise SystemExit(f"error: no row named {name!r} in {path}")


def _check(current: str, baseline: str, metric: str, reference: str | None,
           max_ratio: float) -> bool:
    cur = _row(current, metric)
    base = _row(baseline, metric)
    unit = "us"
    if reference:
        cur /= max(_row(current, reference), 1e-9)
        base /= max(_row(baseline, reference), 1e-9)
        unit = f"x {reference}"
    ratio = cur / max(base, 1e-9)
    ok = ratio <= max_ratio
    print(f"{metric}: current={cur:.3f}{unit} baseline={base:.3f}{unit} "
          f"ratio={ratio:.2f}x (max {max_ratio:.1f}x) -> "
          f"{'OK' if ok else 'REGRESSION'}")
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="freshly produced BENCH_<label>.json")
    ap.add_argument("--baseline", default="results/bench/BENCH_pr3.json")
    ap.add_argument("--metric", default="trace/hlem-vmp-adjusted",
                    help="comma-separated benchmark row names")
    ap.add_argument("--max-ratio", type=float, default=2.0)
    ap.add_argument("--reference-metric", default=None,
                    help="comma-separated same-artifact rows to normalize "
                         "by, paired with --metric ('-' = no normalization)")
    args = ap.parse_args(argv)

    metrics = [m for m in args.metric.split(",") if m]
    refs = (args.reference_metric.split(",")
            if args.reference_metric else [None] * len(metrics))
    if len(refs) != len(metrics):
        raise SystemExit("error: --reference-metric count must match "
                         "--metric count")
    ok = True
    for metric, ref in zip(metrics, refs):
        ref = None if ref in (None, "", "-") else ref
        ok &= _check(args.current, args.baseline, metric, ref,
                     args.max_ratio)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
