"""Beyond-paper: deterministic interruption-victim selection strategies
(the paper's §IX future-work item) vs the faithful list-order default."""
from __future__ import annotations

from repro.core import ScenarioConfig

from .common import emit, run_market

SELECTORS = ["list_order", "best_fit_remaining", "max_progress"]


def run(quick: bool = True):
    rows = []
    for sel in SELECTORS:
        sim, metrics, wall = run_market("hlem-vmp-adjusted",
                                        ScenarioConfig(seed=0), selector=sel)
        s = metrics.spot_stats(sim.vms)
        rows.append(emit(
            f"victim/{sel}", wall * 1e6 / max(metrics.allocations, 1),
            f"interruptions={s['interruptions']};"
            f"avg_s={s['avg_interruption_time']:.2f};"
            f"max_s={s['max_interruption_time']:.2f};"
            f"terminated={s['spot_terminated']}"))
    return rows
